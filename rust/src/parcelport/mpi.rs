//! MPI parcelport — OpenMPI-semantics transport with eager/rendezvous
//! protocol.
//!
//! Heller's MPI parcelport maps parcels onto MPI point-to-point calls, so
//! its costs are MPI's costs:
//!
//! - **eager path** (≤ [`EAGER_THRESHOLD`]): the payload is copied into a
//!   bounce buffer on send (a real `memcpy` here, counted in stats) and
//!   delivered immediately — one protocol copy, low latency;
//! - **rendezvous path** (> threshold): the sender posts an RTS control
//!   message and parks the payload; when the receiver matches the RTS
//!   (inside `recv`/`try_recv` — receiver-driven progression, which is
//!   how MPI implementations progress rendezvous while the application
//!   blocks in `MPI_Recv`) it grants CTS and the transfer completes
//!   zero-copy (the RDMA analog). This adds one RTT of handshake latency
//!   but no copy — exactly the crossover the cost model encodes.
//!
//! Sends never block the caller, so symmetric exchange patterns (pairwise
//! all-to-all) cannot deadlock — pinned by `symmetric_exchange_no_deadlock`.

use super::cost::NetModel;
use super::stats::{PortStats, PortStatsSnapshot};
use super::{Parcelport, PortKind};
use crate::hpx::mailbox::Mailbox;
use crate::hpx::parcel::{actions, ActionId, LocalityId, Parcel, Payload, Tag};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Mutex;

/// OpenMPI's default eager limit for large-message transports (64 KiB).
pub const EAGER_THRESHOLD: usize = 64 * 1024;

type PendingKey = (LocalityId, LocalityId, ActionId, Tag); // (src, dest, action, tag)

/// MPI-semantics fabric.
pub struct MpiParcelport {
    mailboxes: Vec<Mailbox>,
    stats: PortStats,
    net: Option<NetModel>,
    /// Parked rendezvous payloads awaiting CTS.
    pending: Mutex<HashMap<PendingKey, Payload>>,
    uid: u64,
}

impl MpiParcelport {
    /// Build an MPI-semantics fabric connecting `n_localities` localities.
    pub fn new(n_localities: usize, net: Option<NetModel>) -> Self {
        assert!(n_localities > 0, "fabric needs at least one locality");
        Self {
            mailboxes: (0..n_localities).map(|_| Mailbox::new()).collect(),
            stats: PortStats::default(),
            net,
            pending: Mutex::new(HashMap::new()),
            uid: super::next_port_uid(),
        }
    }

    /// Complete a matched rendezvous: take the parked payload (zero-copy)
    /// and charge the handshake RTT.
    fn complete_rendezvous(&self, key: PendingKey) -> Payload {
        let payload =
            self.pending.lock().unwrap().remove(&key).expect("RTS without parked payload");
        if let Some(net) = &self.net {
            let rtts = PortKind::Mpi.cost_model().rendezvous_rtts as f64;
            super::cost::spin_for(std::time::Duration::from_nanos(
                (rtts * 2.0 * net.alpha_us * 1e3) as u64,
            ));
        }
        self.stats.rendezvous_handshakes.fetch_add(1, Ordering::Relaxed);
        payload
    }
}

impl Parcelport for MpiParcelport {
    fn kind(&self) -> PortKind {
        PortKind::Mpi
    }

    fn n_localities(&self) -> usize {
        self.mailboxes.len()
    }

    fn uid(&self) -> u64 {
        self.uid
    }

    fn send(&self, parcel: Parcel) {
        assert!(parcel.dest < self.n_localities(), "dest {} out of range", parcel.dest);
        let size = parcel.payload.len();
        self.stats.record_send(size);
        // One trace span per physical send, next to the one record_send —
        // the invariant audit test holds traced bytes equal to PortStats.
        let _span = crate::obs::span_args(
            "port",
            "send",
            parcel.src,
            parcel.tag as i64,
            crate::obs::NO_ARG,
            size as i64,
        );
        if parcel.src != parcel.dest {
            if let Some(net) = &self.net {
                let us = net.charge(&PortKind::Mpi.cost_model(), size as u64);
                self.stats.modeled_wire_us.fetch_add(us as u64, Ordering::Relaxed);
            }
        }
        if size <= EAGER_THRESHOLD || parcel.src == parcel.dest {
            // Eager: copy through the bounce buffer (the protocol copy).
            // Self-sends always take this path (MPI self-communication is
            // a local copy, never RDMA).
            self.stats.eager_sends.fetch_add(1, Ordering::Relaxed);
            self.stats.record_copy(size);
            let copied = Parcel { payload: parcel.payload.deep_copy(), ..parcel };
            self.mailboxes[copied.dest].deliver(copied);
        } else {
            // Rendezvous: park the payload, post RTS to the receiver.
            let key: PendingKey = (parcel.src, parcel.dest, parcel.action, parcel.tag);
            self.pending.lock().unwrap().insert(key, parcel.payload);
            let rts = Parcel::new(
                parcel.src,
                parcel.dest,
                actions::CTRL_RTS,
                rts_tag(parcel.action, parcel.tag),
                Payload::empty(),
            );
            self.mailboxes[parcel.dest].deliver(rts);
        }
    }

    fn recv(&self, at: LocalityId, src: LocalityId, action: ActionId, tag: Tag) -> Payload {
        let _span = crate::obs::span_args(
            "port",
            "recv",
            at,
            tag as i64,
            crate::obs::NO_ARG,
            crate::obs::NO_ARG,
        );
        // Fast path: data already here (eager, or rendezvous completed).
        if let Some(p) = self.mailboxes[at].try_recv(src, action, tag) {
            return p;
        }
        loop {
            // If the matching RTS is queued, grant CTS and complete the
            // rendezvous inline.
            if self.mailboxes[at].try_recv(src, actions::CTRL_RTS, rts_tag(action, tag)).is_some()
            {
                return self.complete_rendezvous((src, at, action, tag));
            }
            // Otherwise block (short timeout so a late RTS is noticed).
            if let Some(p) = self.mailboxes[at].recv_timeout(
                src,
                action,
                tag,
                std::time::Duration::from_micros(200),
            ) {
                return p;
            }
        }
    }

    fn try_recv(
        &self,
        at: LocalityId,
        src: LocalityId,
        action: ActionId,
        tag: Tag,
    ) -> Option<Payload> {
        if let Some(p) = self.mailboxes[at].try_recv(src, action, tag) {
            return Some(p);
        }
        if self.mailboxes[at].try_recv(src, actions::CTRL_RTS, rts_tag(action, tag)).is_some() {
            return Some(self.complete_rendezvous((src, at, action, tag)));
        }
        None
    }

    fn stats(&self) -> PortStatsSnapshot {
        self.stats.snapshot()
    }

    fn mailbox(&self, at: LocalityId) -> &Mailbox {
        &self.mailboxes[at]
    }
}

/// RTS control messages ride the CTRL_RTS action with a tag that folds in
/// the data action so (action, tag) pairs from different collectives
/// cannot collide.
fn rts_tag(action: ActionId, tag: Tag) -> Tag {
    ((action as Tag) << 48) ^ tag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::parcel::actions;

    #[test]
    fn eager_path_copies() {
        let port = MpiParcelport::new(2, None);
        let payload = Payload::new(vec![7u8; 1024]);
        port.send(Parcel::new(0, 1, actions::P2P, 1, payload.clone()));
        let got = port.recv(1, 0, actions::P2P, 1);
        assert!(!got.shares_storage(&payload), "eager path must copy");
        assert_eq!(got.as_bytes(), payload.as_bytes());
        let st = port.stats();
        assert_eq!(st.eager_sends, 1);
        assert_eq!(st.payload_copies, 1);
        assert_eq!(st.rendezvous_handshakes, 0);
    }

    #[test]
    fn rendezvous_path_zero_copy() {
        let port = MpiParcelport::new(2, None);
        let payload = Payload::new(vec![3u8; EAGER_THRESHOLD + 1]);
        port.send(Parcel::new(0, 1, actions::P2P, 2, payload.clone()));
        let got = port.recv(1, 0, actions::P2P, 2);
        assert!(got.shares_storage(&payload), "rendezvous completes zero-copy");
        let st = port.stats();
        assert_eq!(st.rendezvous_handshakes, 1);
        assert_eq!(st.eager_sends, 0);
    }

    #[test]
    fn boundary_size_is_eager() {
        let port = MpiParcelport::new(2, None);
        port.send(Parcel::new(0, 1, actions::P2P, 3, Payload::new(vec![0; EAGER_THRESHOLD])));
        port.recv(1, 0, actions::P2P, 3);
        assert_eq!(port.stats().eager_sends, 1);
    }

    #[test]
    fn recv_before_send_rendezvous() {
        // Receiver arrives first; sender's RTS must wake it.
        let port = std::sync::Arc::new(MpiParcelport::new(2, None));
        let p2 = std::sync::Arc::clone(&port);
        let h = std::thread::spawn(move || p2.recv(1, 0, actions::P2P, 4).len());
        std::thread::sleep(std::time::Duration::from_millis(10));
        port.send(Parcel::new(0, 1, actions::P2P, 4, Payload::new(vec![0; 200_000])));
        assert_eq!(h.join().unwrap(), 200_000);
    }

    #[test]
    fn symmetric_exchange_no_deadlock() {
        // Every rank sends a rendezvous-sized message to every other rank
        // and then receives — the pattern that deadlocks naive blocking
        // rendezvous.
        let n = 4;
        let port = MpiParcelport::new(n, None);
        std::thread::scope(|s| {
            for me in 0..n {
                let port = &port;
                s.spawn(move || {
                    for dst in 0..n {
                        port.send(Parcel::new(
                            me,
                            dst,
                            actions::P2P,
                            5,
                            Payload::new(vec![me as u8; 100_000]),
                        ));
                    }
                    for src in 0..n {
                        let p = port.recv(me, src, actions::P2P, 5);
                        assert_eq!(p.as_bytes()[0], src as u8);
                    }
                });
            }
        });
    }

    #[test]
    fn self_send_is_eager_even_when_large() {
        let port = MpiParcelport::new(1, None);
        port.send(Parcel::new(0, 0, actions::P2P, 6, Payload::new(vec![1; 500_000])));
        assert_eq!(port.recv(0, 0, actions::P2P, 6).len(), 500_000);
    }

    #[test]
    fn try_recv_progresses_rendezvous() {
        let port = MpiParcelport::new(2, None);
        assert!(port.try_recv(1, 0, actions::P2P, 7).is_none());
        port.send(Parcel::new(0, 1, actions::P2P, 7, Payload::new(vec![0; 100_000])));
        // RTS is queued; try_recv should complete the handshake.
        let got = port.try_recv(1, 0, actions::P2P, 7);
        assert_eq!(got.unwrap().len(), 100_000);
    }

    #[test]
    fn distinct_tags_do_not_cross_match() {
        let port = MpiParcelport::new(2, None);
        port.send(Parcel::new(0, 1, actions::P2P, 10, Payload::new(vec![1; 100_000])));
        port.send(Parcel::new(0, 1, actions::P2P, 11, Payload::new(vec![2; 100_000])));
        let b = port.recv(1, 0, actions::P2P, 11);
        let a = port.recv(1, 0, actions::P2P, 10);
        assert_eq!(a.as_bytes()[0], 1);
        assert_eq!(b.as_bytes()[0], 2);
    }
}
