//! Parcelports — the three HPX communication backends under benchmark.
//!
//! A parcelport moves [`Parcel`]s between localities. The paper compares
//! three of them; each is rebuilt here with its characteristic *protocol
//! costs* as real code, not as a lookup table:
//!
//! | port | path | protocol costs (real code here) |
//! |------|------|----------------------------------|
//! | [`tcp`] | kernel TCP over loopback sockets | frame encode copy, kernel crossings, per-stream write lock, frame decode copy |
//! | [`mpi`] | in-process fabric | tag matching, eager bounce-buffer copy ≤ threshold, RTS/CTS rendezvous handshake above it, progress engine |
//! | [`lci`] | in-process fabric | zero-copy `Arc` handoff, no matching beyond the mailbox, no handshake |
//!
//! On top of the real protocol work, an optional [`NetModel`] charges the
//! *wire* time of the paper's InfiniBand HDR links (α + size/β plus a
//! per-port software overhead) by spinning the sending thread — this is
//! the "hybrid" mode used by the figure harnesses for small clusters;
//! cluster-scale predictions use [`crate::simnet`] instead.

pub mod cost;
pub mod faulty;
pub mod lci;
pub mod mpi;
pub mod scoped;
pub mod stats;
pub mod tcp;

use crate::hpx::mailbox::Mailbox;
use crate::hpx::parcel::{ActionId, LocalityId, Parcel, Payload, Tag};
pub use cost::{CostModel, NetModel};
pub use faulty::{FaultSpec, FaultyPort};
pub use scoped::ScopedPort;
pub use stats::{PortStats, PortStatsSnapshot};

use std::str::FromStr;
use std::sync::Arc;

/// Which backend a fabric implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PortKind {
    /// Kernel TCP over loopback sockets.
    Tcp,
    /// MPI-semantics in-process fabric (eager/rendezvous protocol).
    Mpi,
    /// LCI-semantics in-process fabric (zero-copy handoff).
    Lci,
}

impl PortKind {
    /// All three backends, in the paper's presentation order.
    pub const ALL: [PortKind; 3] = [PortKind::Tcp, PortKind::Mpi, PortKind::Lci];

    /// Lowercase backend name (CLI / CSV spelling).
    pub fn name(&self) -> &'static str {
        match self {
            PortKind::Tcp => "tcp",
            PortKind::Mpi => "mpi",
            PortKind::Lci => "lci",
        }
    }

    /// The port's software cost model (calibrated — see DESIGN.md §6).
    pub fn cost_model(&self) -> CostModel {
        match self {
            PortKind::Tcp => CostModel::tcp(),
            PortKind::Mpi => CostModel::mpi(),
            PortKind::Lci => CostModel::lci(),
        }
    }
}

impl FromStr for PortKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "tcp" => Ok(PortKind::Tcp),
            "mpi" => Ok(PortKind::Mpi),
            "lci" => Ok(PortKind::Lci),
            other => Err(format!("unknown parcelport {other:?} (expected tcp|mpi|lci)")),
        }
    }
}

impl std::fmt::Display for PortKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A wired-up communication fabric connecting `n_localities` localities.
///
/// `send` is non-blocking from the caller's perspective (rendezvous
/// completion is driven by the port's progress engine); `recv` is a
/// blocking matched receive at a locality.
pub trait Parcelport: Send + Sync {
    /// Which backend this fabric implements.
    fn kind(&self) -> PortKind;
    /// Number of localities the fabric connects.
    fn n_localities(&self) -> usize;

    /// Process-unique fabric identity: stable for the fabric's lifetime
    /// and never reused within the process (unlike an `Arc` address), so
    /// diagnostics — notably the conformance checker's per-fabric
    /// wait-for graph ([`crate::collectives::conformance`]) — can key
    /// state by it without confusing a dead fabric with a new one that
    /// reuses its allocation. Decorators (stats scopes, fault injectors)
    /// forward their inner fabric's id: one logical fabric, one id.
    fn uid(&self) -> u64;

    /// Queue a parcel for delivery. Payload semantics (copy vs. share)
    /// are port-specific — that difference is the benchmark.
    fn send(&self, parcel: Parcel);

    /// Blocking matched receive at locality `at`.
    fn recv(&self, at: LocalityId, src: LocalityId, action: ActionId, tag: Tag) -> Payload;

    /// Non-blocking matched receive at locality `at`.
    fn try_recv(&self, at: LocalityId, src: LocalityId, action: ActionId, tag: Tag)
        -> Option<Payload>;

    /// Cumulative traffic statistics.
    fn stats(&self) -> PortStatsSnapshot;

    /// Direct mailbox access (runtime internals, tests).
    fn mailbox(&self, at: LocalityId) -> &Mailbox;
}

/// Allocate a fresh [`Parcelport::uid`] (called by port constructors;
/// decorators forward instead).
pub(crate) fn next_port_uid() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Build a fabric of the given kind.
///
/// `net` is the optional wire model applied on top of the port's real
/// protocol work (pass `None` for raw local performance).
pub fn build(
    kind: PortKind,
    n_localities: usize,
    net: Option<NetModel>,
) -> anyhow::Result<Arc<dyn Parcelport>> {
    Ok(match kind {
        PortKind::Tcp => Arc::new(tcp::TcpParcelport::new(n_localities, net)?),
        PortKind::Mpi => Arc::new(mpi::MpiParcelport::new(n_localities, net)),
        PortKind::Lci => Arc::new(lci::LciParcelport::new(n_localities, net)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::parcel::actions;

    #[test]
    fn port_kind_parse() {
        assert_eq!("tcp".parse::<PortKind>().unwrap(), PortKind::Tcp);
        assert_eq!("MPI".parse::<PortKind>().unwrap(), PortKind::Mpi);
        assert_eq!("lci".parse::<PortKind>().unwrap(), PortKind::Lci);
        assert!("ucx".parse::<PortKind>().is_err());
    }

    #[test]
    fn port_kind_display_roundtrip() {
        for kind in PortKind::ALL {
            assert_eq!(kind.name().parse::<PortKind>().unwrap(), kind);
        }
    }

    /// Contract test run against every backend: point-to-point delivery,
    /// matching, ordering, and payload integrity.
    fn exercise_port(fabric: &dyn Parcelport) {
        let n = fabric.n_localities();
        std::thread::scope(|s| {
            for me in 0..n {
                s.spawn(move || {
                    // Send one message to every locality (incl. self).
                    for dst in 0..n {
                        let data: Vec<f32> = vec![me as f32 + dst as f32 * 0.5; 64];
                        fabric.send(Parcel::new(
                            me,
                            dst,
                            actions::P2P,
                            7,
                            Payload::from_f32(&data),
                        ));
                    }
                    // Receive one from every locality.
                    for src in 0..n {
                        let p = fabric.recv(me, src, actions::P2P, 7);
                        let expect: Vec<f32> = vec![src as f32 + me as f32 * 0.5; 64];
                        assert_eq!(p.to_f32(), expect, "at {me} from {src}");
                    }
                });
            }
        });
        let st = fabric.stats();
        assert!(st.msgs_sent >= (n * n) as u64, "stats should count sends: {st:?}");
    }

    #[test]
    fn contract_lci() {
        exercise_port(&lci::LciParcelport::new(4, None));
    }

    #[test]
    fn contract_mpi() {
        exercise_port(&mpi::MpiParcelport::new(4, None));
    }

    #[test]
    fn contract_tcp() {
        exercise_port(&tcp::TcpParcelport::new(4, None).unwrap());
    }

    #[test]
    fn build_constructs_all() {
        for kind in PortKind::ALL {
            let fabric = build(kind, 2, None).unwrap();
            assert_eq!(fabric.kind(), kind);
            assert_eq!(fabric.n_localities(), 2);
        }
    }
}
