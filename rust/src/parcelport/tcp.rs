//! TCP parcelport — real kernel sockets over loopback.
//!
//! HPX's original parcelport (Heller): parcels are serialized into
//! length-prefixed frames and written to per-pair TCP streams. Every cost
//! that makes TCP slow for small chunks in the paper's Fig. 3 is incurred
//! for real here:
//!
//! - the frame-encode copy (header + payload into one buffer),
//! - two kernel crossings (write + read) through the loopback stack,
//! - per-stream write serialization (one in-flight frame per pair),
//! - the frame-decode copy into a fresh payload allocation.
//!
//! Topology: a full mesh. Each locality binds an ephemeral listener;
//! locality `i` dials `j` for `i < j`, and the accept side learns the
//! dialer's id from a one-byte hello. One reader thread per stream parses
//! frames and files them into the destination mailbox. Self-sends bypass
//! the socket (matching HPX, which short-circuits local parcels) but
//! still pay the encode/decode copies.

use super::cost::NetModel;
use super::stats::{PortStats, PortStatsSnapshot};
use super::{Parcelport, PortKind};
use crate::hpx::mailbox::Mailbox;
use crate::hpx::parcel::{ActionId, LocalityId, Parcel, Payload, Tag};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Kernel-TCP fabric.
pub struct TcpParcelport {
    inner: Arc<Inner>,
    readers: Mutex<Vec<JoinHandle<()>>>,
    uid: u64,
}

struct Inner {
    n: usize,
    mailboxes: Vec<Mailbox>,
    /// writers[me][peer] — stream for me→peer traffic (None on diagonal).
    writers: Vec<Vec<Option<Mutex<TcpStream>>>>,
    stats: PortStats,
    net: Option<NetModel>,
}

impl TcpParcelport {
    /// Bind loopback listeners and fully mesh `n_localities` localities.
    pub fn new(n_localities: usize, net: Option<NetModel>) -> Result<Self> {
        assert!(n_localities > 0, "fabric needs at least one locality");
        let n = n_localities;

        // Bind one ephemeral listener per locality.
        let listeners: Vec<TcpListener> = (0..n)
            .map(|i| {
                TcpListener::bind("127.0.0.1:0")
                    .with_context(|| format!("bind listener for locality {i}"))
            })
            .collect::<Result<_>>()?;
        let addrs: Vec<_> =
            listeners.iter().map(|l| l.local_addr().expect("listener addr")).collect();

        // Dial the upper triangle: i → j for i < j. Accepts happen on a
        // helper thread per listener so dialing cannot deadlock.
        let acceptors: Vec<JoinHandle<Result<Vec<(LocalityId, TcpStream)>>>> = listeners
            .into_iter()
            .enumerate()
            .map(|(j, listener)| {
                std::thread::spawn(move || {
                    let mut peers = Vec::new();
                    for _ in 0..j {
                        let (mut stream, _) = listener.accept().context("accept")?;
                        let mut hello = [0u8; 4];
                        stream.read_exact(&mut hello).context("read hello")?;
                        let dialer = u32::from_le_bytes(hello) as LocalityId;
                        stream.set_nodelay(true).ok();
                        peers.push((dialer, stream));
                    }
                    Ok(peers)
                })
            })
            .collect();

        // writers[i][j]: i's stream to j.
        let mut writers: Vec<Vec<Option<Mutex<TcpStream>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        // reader_streams[i]: streams whose frames are destined for i.
        let mut reader_streams: Vec<Vec<(LocalityId, TcpStream)>> =
            (0..n).map(|_| Vec::new()).collect();

        for i in 0..n {
            for j in (i + 1)..n {
                let mut stream =
                    TcpStream::connect(addrs[j]).with_context(|| format!("dial {i}→{j}"))?;
                stream.set_nodelay(true).ok();
                stream.write_all(&(i as u32).to_le_bytes()).context("send hello")?;
                // The dialed stream is bidirectional: i writes i→j frames,
                // j writes j→i frames on its accepted end.
                let read_half = stream.try_clone().context("clone stream")?;
                writers[i][j] = Some(Mutex::new(stream));
                reader_streams[i].push((j, read_half));
            }
        }
        for (j, acceptor) in acceptors.into_iter().enumerate() {
            for (dialer, stream) in acceptor.join().expect("acceptor panicked")? {
                let read_half = stream.try_clone().context("clone accepted stream")?;
                writers[j][dialer] = Some(Mutex::new(stream));
                reader_streams[j].push((dialer, read_half));
            }
        }

        let inner = Arc::new(Inner {
            n,
            mailboxes: (0..n).map(|_| Mailbox::new()).collect(),
            writers,
            stats: PortStats::default(),
            net,
        });

        // One reader thread per stream.
        let mut readers = Vec::new();
        for (me, streams) in reader_streams.into_iter().enumerate() {
            for (peer, stream) in streams {
                let inner = Arc::clone(&inner);
                readers.push(
                    std::thread::Builder::new()
                        .name(format!("tcp-rx-{me}-from-{peer}"))
                        .spawn(move || reader_loop(stream, &inner, me))
                        .expect("spawn reader"),
                );
            }
        }

        Ok(Self { inner, readers: Mutex::new(readers), uid: super::next_port_uid() })
    }
}

/// Parse length-prefixed frames off one stream and file them.
fn reader_loop(mut stream: TcpStream, inner: &Inner, me: LocalityId) {
    loop {
        let mut len_buf = [0u8; 8];
        match stream.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(_) => return, // peer closed: fabric teardown
        }
        let frame_len = u64::from_le_bytes(len_buf) as usize;
        let mut frame = vec![0u8; frame_len];
        if stream.read_exact(&mut frame).is_err() {
            return;
        }
        // Decode copies the payload out of the frame (counted).
        let parcel = Parcel::decode(&frame);
        inner.stats.record_copy(parcel.payload.len());
        debug_assert_eq!(parcel.dest, me, "frame routed to wrong locality");
        inner.mailboxes[me].deliver(parcel);
    }
}

impl Parcelport for TcpParcelport {
    fn kind(&self) -> PortKind {
        PortKind::Tcp
    }

    fn n_localities(&self) -> usize {
        self.inner.n
    }

    fn uid(&self) -> u64 {
        self.uid
    }

    fn send(&self, parcel: Parcel) {
        let inner = &self.inner;
        assert!(parcel.dest < inner.n, "dest {} out of range", parcel.dest);
        inner.stats.record_send(parcel.payload.len());
        // One trace span per physical send, next to the one record_send —
        // the invariant audit test holds traced bytes equal to PortStats.
        let _span = crate::obs::span_args(
            "port",
            "send",
            parcel.src,
            parcel.tag as i64,
            crate::obs::NO_ARG,
            parcel.payload.len() as i64,
        );
        if parcel.src != parcel.dest {
            if let Some(net) = &inner.net {
                let us = net.charge(&PortKind::Tcp.cost_model(), parcel.payload.len() as u64);
                inner.stats.modeled_wire_us.fetch_add(us as u64, Ordering::Relaxed);
            }
        }

        // Frame-encode copy (header + payload into one buffer).
        let frame = parcel.encode();
        inner.stats.record_copy(frame.len());

        if parcel.src == parcel.dest {
            // Local short-circuit: still decode (the second copy), skip
            // the kernel.
            let decoded = Parcel::decode(&frame);
            inner.stats.record_copy(decoded.payload.len());
            inner.mailboxes[parcel.dest].deliver(decoded);
            return;
        }

        let writer = inner.writers[parcel.src][parcel.dest]
            .as_ref()
            .expect("missing stream for pair");
        let mut stream = writer.lock().unwrap();
        stream
            .write_all(&(frame.len() as u64).to_le_bytes())
            .and_then(|_| stream.write_all(&frame))
            .expect("tcp write failed");
    }

    fn recv(&self, at: LocalityId, src: LocalityId, action: ActionId, tag: Tag) -> Payload {
        let _span = crate::obs::span_args(
            "port",
            "recv",
            at,
            tag as i64,
            crate::obs::NO_ARG,
            crate::obs::NO_ARG,
        );
        self.inner.mailboxes[at].recv(src, action, tag)
    }

    fn try_recv(
        &self,
        at: LocalityId,
        src: LocalityId,
        action: ActionId,
        tag: Tag,
    ) -> Option<Payload> {
        self.inner.mailboxes[at].try_recv(src, action, tag)
    }

    fn stats(&self) -> PortStatsSnapshot {
        self.inner.stats.snapshot()
    }

    fn mailbox(&self, at: LocalityId) -> &Mailbox {
        &self.inner.mailboxes[at]
    }
}

impl Drop for TcpParcelport {
    fn drop(&mut self) {
        // Shut down every stream so reader threads see EOF and exit.
        for row in &self.inner.writers {
            for w in row.iter().flatten() {
                let _ = w.lock().unwrap().shutdown(std::net::Shutdown::Both);
            }
        }
        for h in self.readers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::parcel::actions;

    #[test]
    fn basic_delivery() {
        let port = TcpParcelport::new(2, None).unwrap();
        let data: Vec<f32> = (0..256).map(|i| i as f32).collect();
        port.send(Parcel::new(0, 1, actions::P2P, 42, Payload::from_f32(&data)));
        let got = port.recv(1, 0, actions::P2P, 42);
        assert_eq!(got.to_f32(), data);
    }

    #[test]
    fn payload_is_copied_not_shared() {
        let port = TcpParcelport::new(2, None).unwrap();
        let payload = Payload::from_f32(&[5.0; 32]);
        port.send(Parcel::new(0, 1, actions::P2P, 1, payload.clone()));
        let got = port.recv(1, 0, actions::P2P, 1);
        assert!(!got.shares_storage(&payload), "TCP must deep-copy through the socket");
        assert_eq!(got.as_bytes(), payload.as_bytes());
        // Two copies per off-node message: encode + decode.
        assert!(port.stats().payload_copies >= 2);
    }

    #[test]
    fn bidirectional_same_pair() {
        let port = TcpParcelport::new(2, None).unwrap();
        port.send(Parcel::new(0, 1, actions::P2P, 1, Payload::new(vec![1])));
        port.send(Parcel::new(1, 0, actions::P2P, 2, Payload::new(vec![2])));
        assert_eq!(port.recv(1, 0, actions::P2P, 1).as_bytes(), &[1]);
        assert_eq!(port.recv(0, 1, actions::P2P, 2).as_bytes(), &[2]);
    }

    #[test]
    fn ordering_preserved_per_stream() {
        let port = TcpParcelport::new(2, None).unwrap();
        for i in 0..100u8 {
            port.send(Parcel::new(0, 1, actions::P2P, 9, Payload::new(vec![i])));
        }
        for i in 0..100u8 {
            assert_eq!(port.recv(1, 0, actions::P2P, 9).as_bytes(), &[i]);
        }
    }

    #[test]
    fn large_message_crosses_socket() {
        let port = TcpParcelport::new(2, None).unwrap();
        let data = vec![0xABu8; 4 << 20]; // 4 MiB
        port.send(Parcel::new(0, 1, actions::P2P, 3, Payload::new(data.clone())));
        let got = port.recv(1, 0, actions::P2P, 3);
        assert_eq!(got.as_bytes(), &data[..]);
    }

    #[test]
    fn self_send_short_circuits() {
        let port = TcpParcelport::new(1, None).unwrap();
        port.send(Parcel::new(0, 0, actions::P2P, 4, Payload::new(vec![7; 10])));
        assert_eq!(port.recv(0, 0, actions::P2P, 4).len(), 10);
    }

    #[test]
    fn four_node_mesh_all_pairs() {
        let port = TcpParcelport::new(4, None).unwrap();
        std::thread::scope(|s| {
            for me in 0..4 {
                let port = &port;
                s.spawn(move || {
                    for dst in 0..4 {
                        port.send(Parcel::new(
                            me,
                            dst,
                            actions::P2P,
                            5,
                            Payload::new(vec![(me * 4 + dst) as u8]),
                        ));
                    }
                    for src in 0..4 {
                        let p = port.recv(me, src, actions::P2P, 5);
                        assert_eq!(p.as_bytes(), &[(src * 4 + me) as u8]);
                    }
                });
            }
        });
    }

    #[test]
    fn teardown_joins_cleanly() {
        let port = TcpParcelport::new(3, None).unwrap();
        port.send(Parcel::new(0, 1, actions::P2P, 6, Payload::new(vec![1])));
        port.recv(1, 0, actions::P2P, 6);
        drop(port); // must not hang or panic
    }
}
