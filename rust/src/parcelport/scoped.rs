//! A stats-scoping decorator over any parcelport.
//!
//! [`ScopedPort`] wraps an existing fabric and mirrors every `send` into
//! a private [`PortStats`] scope before delegating, leaving delivery
//! semantics, matching, and the fabric-global counters untouched. It is
//! the attribution mechanism behind per-job wire accounting in the
//! multi-tenant FFT service ([`crate::runtime::FftService`]): when many
//! jobs share one fabric, the global counters interleave, but each job's
//! scope sees only its own traffic.
//!
//! Scope counters cover what the *communicator* sends (`msgs_sent`,
//! `bytes_sent`). Port-internal protocol work — framing/eager copies,
//! rendezvous handshakes, modeled wire time — happens below this
//! decorator and stays in the fabric-global [`Parcelport::stats`], which
//! the wrapper passes through unchanged.

use super::{Parcelport, PortKind, PortStats, PortStatsSnapshot};
use crate::hpx::mailbox::Mailbox;
use crate::hpx::parcel::{ActionId, LocalityId, Parcel, Payload, Tag};
use std::sync::Arc;

/// A parcelport decorator that counts sends into a private scope.
pub struct ScopedPort {
    inner: Arc<dyn Parcelport>,
    scope: Arc<PortStats>,
}

impl ScopedPort {
    /// Wrap `inner`, returning the decorated fabric and the scope its
    /// sends are mirrored into.
    pub fn wrap(inner: Arc<dyn Parcelport>) -> (Arc<dyn Parcelport>, Arc<PortStats>) {
        let scope = Arc::new(PortStats::default());
        let port = Arc::new(ScopedPort { inner, scope: Arc::clone(&scope) });
        (port, scope)
    }
}

impl Parcelport for ScopedPort {
    fn kind(&self) -> PortKind {
        self.inner.kind()
    }

    fn n_localities(&self) -> usize {
        self.inner.n_localities()
    }

    fn uid(&self) -> u64 {
        // One logical fabric, one id: the scope only mirrors counters.
        self.inner.uid()
    }

    fn send(&self, parcel: Parcel) {
        self.scope.record_send(parcel.payload.len());
        self.inner.send(parcel);
    }

    fn recv(&self, at: LocalityId, src: LocalityId, action: ActionId, tag: Tag) -> Payload {
        self.inner.recv(at, src, action, tag)
    }

    fn try_recv(
        &self,
        at: LocalityId,
        src: LocalityId,
        action: ActionId,
        tag: Tag,
    ) -> Option<Payload> {
        self.inner.try_recv(at, src, action, tag)
    }

    fn stats(&self) -> PortStatsSnapshot {
        self.inner.stats()
    }

    fn mailbox(&self, at: LocalityId) -> &Mailbox {
        self.inner.mailbox(at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::parcel::actions;
    use crate::parcelport::lci::LciParcelport;

    #[test]
    fn scope_counts_only_scoped_sends() {
        let fabric: Arc<dyn Parcelport> = Arc::new(LciParcelport::new(2, None));
        let before = fabric.stats();
        let (scoped, scope) = ScopedPort::wrap(Arc::clone(&fabric));

        // A send through the wrapper lands in both the scope and the
        // fabric-global counters.
        scoped.send(Parcel::new(0, 1, actions::P2P, 1, Payload::new(vec![0u8; 64])));
        // A send around the wrapper is invisible to the scope.
        fabric.send(Parcel::new(0, 1, actions::P2P, 2, Payload::new(vec![0u8; 100])));

        let s = scope.snapshot();
        assert_eq!(s.msgs_sent, 1);
        assert_eq!(s.bytes_sent, 64);
        let global = scoped.stats().since(&before);
        assert_eq!(global.msgs_sent, 2, "global stats pass through the wrapper");
        assert_eq!(global.bytes_sent, 164);
    }

    #[test]
    fn delivery_passes_through() {
        let fabric: Arc<dyn Parcelport> = Arc::new(LciParcelport::new(2, None));
        let (scoped, _scope) = ScopedPort::wrap(Arc::clone(&fabric));
        assert_eq!(scoped.kind(), fabric.kind());
        assert_eq!(scoped.n_localities(), 2);
        scoped.send(Parcel::new(0, 1, actions::P2P, 9, Payload::from_f32(&[2.5])));
        // Receivable through the wrapper and through the raw fabric alike.
        let p = scoped.recv(1, 0, actions::P2P, 9);
        assert_eq!(p.to_f32(), vec![2.5]);
        assert!(scoped.try_recv(1, 0, actions::P2P, 9).is_none());
    }
}
