//! LCI parcelport — the Lightweight Communication Interface analog.
//!
//! Yan et al. (SC'23 workshops) built the LCI parcelport to bypass MPI's
//! heavyweight machinery: no tag-matching queues beyond the completion
//! queue itself, no eager bounce buffers, and direct hand-off of message
//! buffers. The analog here is deliberately thin: a send is an `Arc`
//! clone of the payload delivered straight into the destination mailbox —
//! **zero payload copies**, which the `zero_copy_identity` test pins down
//! as a structural property, not an implementation accident.

use super::cost::NetModel;
use super::stats::{PortStats, PortStatsSnapshot};
use super::{Parcelport, PortKind};
use crate::hpx::mailbox::Mailbox;
use crate::hpx::parcel::{ActionId, LocalityId, Parcel, Payload, Tag};
use std::sync::atomic::Ordering;

/// Zero-copy in-process fabric.
pub struct LciParcelport {
    mailboxes: Vec<Mailbox>,
    stats: PortStats,
    net: Option<NetModel>,
    uid: u64,
}

impl LciParcelport {
    /// Build a zero-copy fabric connecting `n_localities` localities.
    pub fn new(n_localities: usize, net: Option<NetModel>) -> Self {
        assert!(n_localities > 0, "fabric needs at least one locality");
        Self {
            mailboxes: (0..n_localities).map(|_| Mailbox::new()).collect(),
            stats: PortStats::default(),
            net,
            uid: super::next_port_uid(),
        }
    }
}

impl Parcelport for LciParcelport {
    fn kind(&self) -> PortKind {
        PortKind::Lci
    }

    fn n_localities(&self) -> usize {
        self.mailboxes.len()
    }

    fn uid(&self) -> u64 {
        self.uid
    }

    fn send(&self, parcel: Parcel) {
        assert!(parcel.dest < self.mailboxes.len(), "dest {} out of range", parcel.dest);
        self.stats.record_send(parcel.payload.len());
        // One trace span per physical send, next to the one record_send —
        // the invariant audit test holds traced bytes equal to PortStats.
        let _span = crate::obs::span_args(
            "port",
            "send",
            parcel.src,
            parcel.tag as i64,
            crate::obs::NO_ARG,
            parcel.payload.len() as i64,
        );
        // Hybrid mode: charge modeled software + wire time (self-sends
        // never touch the wire).
        if parcel.src != parcel.dest {
            if let Some(net) = &self.net {
                let us = net.charge(&PortKind::Lci.cost_model(), parcel.payload.len() as u64);
                self.stats.modeled_wire_us.fetch_add(us as u64, Ordering::Relaxed);
            }
        }
        // The LCI path: the payload Arc is handed to the receiver as-is.
        self.mailboxes[parcel.dest].deliver(parcel);
    }

    fn recv(&self, at: LocalityId, src: LocalityId, action: ActionId, tag: Tag) -> Payload {
        let _span = crate::obs::span_args(
            "port",
            "recv",
            at,
            tag as i64,
            crate::obs::NO_ARG,
            crate::obs::NO_ARG,
        );
        self.mailboxes[at].recv(src, action, tag)
    }

    fn try_recv(
        &self,
        at: LocalityId,
        src: LocalityId,
        action: ActionId,
        tag: Tag,
    ) -> Option<Payload> {
        self.mailboxes[at].try_recv(src, action, tag)
    }

    fn stats(&self) -> PortStatsSnapshot {
        self.stats.snapshot()
    }

    fn mailbox(&self, at: LocalityId) -> &Mailbox {
        &self.mailboxes[at]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::parcel::actions;

    #[test]
    fn zero_copy_identity() {
        // The receiver must observe the *same allocation* the sender
        // provided: this is the structural property that distinguishes
        // the LCI port from MPI/TCP.
        let port = LciParcelport::new(2, None);
        let payload = Payload::from_f32(&[1.0; 1024]);
        port.send(Parcel::new(0, 1, actions::P2P, 1, payload.clone()));
        let got = port.recv(1, 0, actions::P2P, 1);
        assert!(got.shares_storage(&payload), "LCI must not copy the payload");
        assert_eq!(port.stats().payload_copies, 0);
        assert_eq!(port.stats().bytes_copied, 0);
    }

    #[test]
    fn sliced_payload_stays_zero_copy() {
        // A wire chunk produced by `Payload::slice` must hand the same
        // allocation to the receiver — the chunked-collective guarantee.
        let port = LciParcelport::new(2, None);
        let whole = Payload::new(vec![9u8; 4096]);
        let chunk = whole.slice(1024, 2048);
        port.send(Parcel::new(0, 1, actions::P2P, 2, chunk.clone()));
        let got = port.recv(1, 0, actions::P2P, 2);
        assert!(got.shares_storage(&whole), "slice chunk must not be copied");
        assert_eq!(got.as_bytes(), chunk.as_bytes());
        assert_eq!(port.stats().bytes_copied, 0);
    }

    #[test]
    fn self_send_works() {
        let port = LciParcelport::new(1, None);
        port.send(Parcel::new(0, 0, actions::P2P, 9, Payload::from_f32(&[3.5])));
        assert_eq!(port.recv(0, 0, actions::P2P, 9).to_f32(), vec![3.5]);
    }

    #[test]
    fn stats_count_bytes() {
        let port = LciParcelport::new(2, None);
        port.send(Parcel::new(0, 1, actions::P2P, 0, Payload::new(vec![0u8; 100])));
        port.send(Parcel::new(1, 0, actions::P2P, 0, Payload::new(vec![0u8; 28])));
        let st = port.stats();
        assert_eq!(st.msgs_sent, 2);
        assert_eq!(st.bytes_sent, 128);
    }

    #[test]
    fn modeled_wire_time_accumulates() {
        let port = LciParcelport::new(2, Some(NetModel::infiniband_hdr()));
        port.send(Parcel::new(0, 1, actions::P2P, 0, Payload::new(vec![0u8; 1 << 20])));
        let st = port.stats();
        // 1 MiB at 25 GB/s ≈ 42 µs wire + 2.5 µs sw.
        assert!(st.modeled_wire_us >= 40, "modeled {} µs", st.modeled_wire_us);
    }

    #[test]
    fn self_send_skips_wire_model() {
        let port = LciParcelport::new(1, Some(NetModel::infiniband_hdr()));
        port.send(Parcel::new(0, 0, actions::P2P, 0, Payload::new(vec![0u8; 1 << 20])));
        assert_eq!(port.stats().modeled_wire_us, 0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bad_dest_panics() {
        LciParcelport::new(2, None).send(Parcel::new(0, 5, actions::P2P, 0, Payload::empty()));
    }
}
