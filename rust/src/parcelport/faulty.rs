//! A fault-injecting decorator over any parcelport.
//!
//! [`FaultyPort`] wraps an existing fabric and perturbs *timing* on the
//! send path — seeded per-message delays ("delayed chunks") and a
//! seeded subset of localities whose every send pays an extra charge
//! ("slow ranks") — before delegating delivery untouched. It is the
//! live-thread counterpart of the simulated adversary in
//! [`crate::simnet::adversary`]: the event engine proves the protocol
//! state machines correct under hostile schedules at cluster scale,
//! while this decorator drives the *real* blocking/async code paths
//! (service workers, chunk pools, split sub-communicators) through the
//! same class of schedule perturbation on a handful of OS threads.
//!
//! The decorator never drops, duplicates, or reorders matched messages
//! — the fabric underneath stays reliable — so anything built on top
//! (in particular [`crate::runtime::FftService`] jobs) must still
//! either complete or fail with a typed error, never hang. That is
//! exactly what the service fault-injection tests assert, under the
//! [`crate::util::testkit::with_watchdog`] bounded-wait helper.
//!
//! Decisions are drawn from [`Pcg32`] streams keyed by a message
//! counter and by locality id, so a given spec replays the same fault
//! *distribution* run-to-run; with live threads the counter-to-message
//! assignment races, so (unlike the simnet engine) bit-identical
//! schedules are not promised here.

use super::{Parcelport, PortKind, PortStatsSnapshot};
use crate::hpx::mailbox::Mailbox;
use crate::hpx::parcel::{ActionId, LocalityId, Parcel, Payload, Tag};
use crate::parcelport::cost::spin_for;
use crate::util::rng::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Stream base for per-rank slow decisions (disjoint from the
/// per-message streams, which start at 0).
const RANK_STREAM: u64 = 1 << 41;

/// What a [`FaultyPort`] injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    /// Seed all decision streams are keyed from.
    pub seed: u64,
    /// Percent of sends that pay an extra delay.
    pub delay_prob_pct: u32,
    /// Maximum injected per-send delay, µs.
    pub max_delay_us: u32,
    /// Percent of localities marked slow.
    pub slow_rank_pct: u32,
    /// Extra charge on every send from a slow locality, µs.
    pub slow_send_us: u32,
}

impl FaultSpec {
    /// Delayed chunks only: 40% of sends pay up to 150 µs.
    pub fn delayed_chunks(seed: u64) -> Self {
        Self { seed, delay_prob_pct: 40, max_delay_us: 150, slow_rank_pct: 0, slow_send_us: 0 }
    }

    /// Slow ranks only: half the localities pay 200 µs per send.
    pub fn slow_ranks(seed: u64) -> Self {
        Self { seed, delay_prob_pct: 0, max_delay_us: 0, slow_rank_pct: 50, slow_send_us: 200 }
    }

    /// Both fault classes at once.
    pub fn hostile(seed: u64) -> Self {
        Self { seed, delay_prob_pct: 40, max_delay_us: 150, slow_rank_pct: 50, slow_send_us: 200 }
    }
}

/// A parcelport decorator that injects seeded send-side delays.
pub struct FaultyPort {
    inner: Arc<dyn Parcelport>,
    spec: FaultSpec,
    slow: Vec<bool>,
    next_msg: AtomicU64,
    delays_injected: AtomicU64,
}

impl FaultyPort {
    /// Decorate `inner` with the given fault spec.
    pub fn new(inner: Arc<dyn Parcelport>, spec: FaultSpec) -> Self {
        let slow = (0..inner.n_localities())
            .map(|rank| {
                let mut rng = Pcg32::with_stream(spec.seed, RANK_STREAM + rank as u64);
                rng.next_below(100) < spec.slow_rank_pct
            })
            .collect();
        Self { inner, spec, slow, next_msg: AtomicU64::new(0), delays_injected: AtomicU64::new(0) }
    }

    /// Decorate `inner` and erase to a fabric handle.
    pub fn wrap(inner: Arc<dyn Parcelport>, spec: FaultSpec) -> Arc<dyn Parcelport> {
        Arc::new(Self::new(inner, spec))
    }

    /// Localities marked slow by this spec's seed.
    pub fn slow_ranks(&self) -> Vec<usize> {
        self.slow.iter().enumerate().filter(|(_, &s)| s).map(|(r, _)| r).collect()
    }

    /// Sends that paid an injected delay so far.
    pub fn delays_injected(&self) -> u64 {
        self.delays_injected.load(Ordering::Relaxed)
    }

    /// Injected delay for message `id` sent from `src`, µs.
    fn delay_us(&self, id: u64, src: LocalityId) -> u64 {
        // Fixed draw order, mirroring the simnet adversary: roll, then
        // amount — so the amount stream is stable even when the roll
        // misses.
        let mut rng = Pcg32::with_stream(self.spec.seed, id);
        let roll = rng.next_below(100);
        let amount = rng.next_below(self.spec.max_delay_us.max(1));
        let mut us = 0u64;
        if roll < self.spec.delay_prob_pct {
            us += u64::from(amount);
        }
        if self.slow[src] {
            us += u64::from(self.spec.slow_send_us);
        }
        us
    }
}

impl Parcelport for FaultyPort {
    fn kind(&self) -> PortKind {
        self.inner.kind()
    }

    fn n_localities(&self) -> usize {
        self.inner.n_localities()
    }

    fn uid(&self) -> u64 {
        // One logical fabric, one id: faults only perturb timing.
        self.inner.uid()
    }

    fn send(&self, parcel: Parcel) {
        let id = self.next_msg.fetch_add(1, Ordering::Relaxed);
        let us = self.delay_us(id, parcel.src);
        if us > 0 {
            self.delays_injected.fetch_add(1, Ordering::Relaxed);
            spin_for(Duration::from_micros(us));
        }
        self.inner.send(parcel);
    }

    fn recv(&self, at: LocalityId, src: LocalityId, action: ActionId, tag: Tag) -> Payload {
        self.inner.recv(at, src, action, tag)
    }

    fn try_recv(
        &self,
        at: LocalityId,
        src: LocalityId,
        action: ActionId,
        tag: Tag,
    ) -> Option<Payload> {
        self.inner.try_recv(at, src, action, tag)
    }

    fn stats(&self) -> PortStatsSnapshot {
        self.inner.stats()
    }

    fn mailbox(&self, at: LocalityId) -> &Mailbox {
        self.inner.mailbox(at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hpx::parcel::actions;
    use crate::parcelport::lci::LciParcelport;

    fn fabric(n: usize) -> Arc<dyn Parcelport> {
        Arc::new(LciParcelport::new(n, None))
    }

    #[test]
    fn delivery_is_unchanged_under_faults() {
        let port = FaultyPort::new(fabric(2), FaultSpec::hostile(3));
        port.send(Parcel::new(0, 1, actions::P2P, 5, Payload::from_f32(&[1.25, -2.0])));
        assert_eq!(port.recv(1, 0, actions::P2P, 5).to_f32(), vec![1.25, -2.0]);
        assert!(port.try_recv(1, 0, actions::P2P, 5).is_none());
    }

    #[test]
    fn slow_rank_selection_is_seeded_and_reproducible() {
        let a = FaultyPort::new(fabric(8), FaultSpec::slow_ranks(9));
        let b = FaultyPort::new(fabric(8), FaultSpec::slow_ranks(9));
        assert_eq!(a.slow_ranks(), b.slow_ranks());
        // 100% slow marks everyone; 0% marks no one.
        let all = FaultyPort::new(
            fabric(4),
            FaultSpec { slow_rank_pct: 100, ..FaultSpec::slow_ranks(9) },
        );
        assert_eq!(all.slow_ranks(), vec![0, 1, 2, 3]);
        let none = FaultyPort::new(fabric(4), FaultSpec::delayed_chunks(9));
        assert!(none.slow_ranks().is_empty());
    }

    #[test]
    fn per_message_delay_decisions_are_deterministic() {
        let a = FaultyPort::new(fabric(2), FaultSpec::hostile(77));
        let b = FaultyPort::new(fabric(2), FaultSpec::hostile(77));
        for id in 0..200 {
            assert_eq!(a.delay_us(id, 0), b.delay_us(id, 0), "msg {id}");
            assert_eq!(a.delay_us(id, 1), b.delay_us(id, 1), "msg {id}");
        }
        assert!((0..200).any(|id| a.delay_us(id, 0) > 0), "hostile spec must inject something");
    }

    #[test]
    fn injected_delays_are_counted() {
        let spec = FaultSpec { delay_prob_pct: 100, ..FaultSpec::delayed_chunks(1) };
        let port = FaultyPort::new(fabric(2), spec);
        for i in 0..10 {
            port.send(Parcel::new(0, 1, actions::P2P, i, Payload::new(vec![0u8; 8])));
        }
        assert_eq!(port.delays_injected(), 10);
    }
}
