//! Traffic statistics shared by all parcelports.
//!
//! Counters are updated lock-free on the send/recv paths and snapshotted
//! by the benchmark harness to report copies, handshakes, and volumes per
//! run (the mechanism behind the "why is TCP slow for small chunks"
//! analysis in EXPERIMENTS.md).

use std::sync::atomic::{AtomicU64, Ordering};

/// Live counters (one per fabric).
#[derive(Debug, Default)]
pub struct PortStats {
    /// Parcels sent (wire chunks count individually).
    pub msgs_sent: AtomicU64,
    /// Payload bytes sent.
    pub bytes_sent: AtomicU64,
    /// Payload memcpys performed by the port itself (framing buffers,
    /// eager bounce buffers). Zero-copy ports keep this at 0.
    pub payload_copies: AtomicU64,
    /// Total bytes those protocol copies moved. The chunked-collective
    /// acceptance check pins this flat for LCI while TCP/MPI's grows.
    pub bytes_copied: AtomicU64,
    /// Rendezvous RTS/CTS handshakes completed (MPI port).
    pub rendezvous_handshakes: AtomicU64,
    /// Eager-path sends (MPI port).
    pub eager_sends: AtomicU64,
    /// Microseconds spent charging the wire model (hybrid mode).
    pub modeled_wire_us: AtomicU64,
}

impl PortStats {
    /// Record one sent parcel of `bytes` payload bytes.
    pub fn record_send(&self, bytes: usize) {
        self.msgs_sent.fetch_add(1, Ordering::Relaxed);
        self.bytes_sent.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Record one protocol memcpy of `bytes` payload bytes.
    pub fn record_copy(&self, bytes: usize) {
        self.payload_copies.fetch_add(1, Ordering::Relaxed);
        self.bytes_copied.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Point-in-time copy of all counters.
    pub fn snapshot(&self) -> PortStatsSnapshot {
        PortStatsSnapshot {
            msgs_sent: self.msgs_sent.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            payload_copies: self.payload_copies.load(Ordering::Relaxed),
            bytes_copied: self.bytes_copied.load(Ordering::Relaxed),
            rendezvous_handshakes: self.rendezvous_handshakes.load(Ordering::Relaxed),
            eager_sends: self.eager_sends.load(Ordering::Relaxed),
            modeled_wire_us: self.modeled_wire_us.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PortStatsSnapshot {
    /// Parcels sent.
    pub msgs_sent: u64,
    /// Payload bytes sent.
    pub bytes_sent: u64,
    /// Protocol memcpys performed by the port.
    pub payload_copies: u64,
    /// Bytes those protocol copies moved.
    pub bytes_copied: u64,
    /// Rendezvous RTS/CTS handshakes completed.
    pub rendezvous_handshakes: u64,
    /// Eager-path sends.
    pub eager_sends: u64,
    /// Microseconds charged by the wire model.
    pub modeled_wire_us: u64,
}

impl PortStatsSnapshot {
    /// Difference since an earlier snapshot (per-run accounting).
    pub fn since(&self, earlier: &PortStatsSnapshot) -> PortStatsSnapshot {
        PortStatsSnapshot {
            msgs_sent: self.msgs_sent - earlier.msgs_sent,
            bytes_sent: self.bytes_sent - earlier.bytes_sent,
            payload_copies: self.payload_copies - earlier.payload_copies,
            bytes_copied: self.bytes_copied - earlier.bytes_copied,
            rendezvous_handshakes: self.rendezvous_handshakes - earlier.rendezvous_handshakes,
            eager_sends: self.eager_sends - earlier.eager_sends,
            modeled_wire_us: self.modeled_wire_us - earlier.modeled_wire_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let st = PortStats::default();
        st.record_send(100);
        st.record_send(50);
        st.record_copy(64);
        let snap = st.snapshot();
        assert_eq!(snap.msgs_sent, 2);
        assert_eq!(snap.bytes_sent, 150);
        assert_eq!(snap.payload_copies, 1);
        assert_eq!(snap.bytes_copied, 64);
    }

    #[test]
    fn copy_bytes_accumulate() {
        let st = PortStats::default();
        st.record_copy(100);
        st.record_copy(28);
        let snap = st.snapshot();
        assert_eq!(snap.payload_copies, 2);
        assert_eq!(snap.bytes_copied, 128);
    }

    #[test]
    fn since_subtracts() {
        let st = PortStats::default();
        st.record_send(10);
        let a = st.snapshot();
        st.record_send(20);
        st.record_send(30);
        let b = st.snapshot();
        let d = b.since(&a);
        assert_eq!(d.msgs_sent, 2);
        assert_eq!(d.bytes_sent, 50);
    }
}
