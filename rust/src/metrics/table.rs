//! Aligned console tables (paper-style result rows).

/// Format a µs quantity for a table cell: exact zero renders as `-` (so
/// the `overlap_us` column stays readable for modes that hide nothing),
/// sub-millisecond values as `12.3 µs`, larger ones as `4.56 ms`.
pub fn fmt_us(us: f64) -> String {
    if us == 0.0 {
        "-".into()
    } else if us < 1000.0 {
        format!("{us:.1} µs")
    } else {
        format!("{:.2} ms", us / 1e3)
    }
}

/// Minimal column-aligned table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render to an aligned multi-line string.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cells[i], width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1))));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name") && lines[0].contains("value"));
        assert!(lines[3].starts_with("longer"));
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn mismatched_row_panics() {
        Table::new(&["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn fmt_us_units() {
        assert_eq!(fmt_us(0.0), "-");
        assert_eq!(fmt_us(12.34), "12.3 µs");
        assert_eq!(fmt_us(4560.0), "4.56 ms");
    }
}
