//! Run statistics with 95% confidence intervals (the paper's error bars).

/// Summary statistics over repeated measurements.
#[derive(Clone, Debug)]
pub struct RunStats {
    /// Samples sorted ascending at construction — every quantile
    /// accessor below is a rank lookup. The previous layout kept the
    /// insertion order and re-cloned-and-sorted inside *each* of
    /// `median`/`p50`/`p95`/`p99`, which the load harness called per
    /// tenant per report line.
    samples: Vec<f64>,
}

impl RunStats {
    /// Wrap a non-empty sample set (sorted here, once).
    ///
    /// # Panics
    /// If `samples` is empty, or any sample is NaN or infinite — a
    /// poisoned timing sample would otherwise corrupt every derived
    /// statistic (and, before this check, a single NaN panicked the
    /// harness deep inside `median`'s sort, mid-sweep, with no hint of
    /// which sample was bad).
    pub fn new(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "need at least one sample");
        if let Some((i, bad)) =
            samples.iter().enumerate().find(|(_, s)| !s.is_finite())
        {
            panic!("sample {i} is not finite ({bad}): RunStats requires finite timing samples");
        }
        // `total_cmp`, not `partial_cmp(..).unwrap()`: NaN is already
        // rejected, but a total order keeps the sort panic-free by
        // construction.
        samples.sort_by(f64::total_cmp);
        Self { samples }
    }

    /// Sample count.
    pub fn n(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.n() as f64
    }

    /// Sample standard deviation (Bessel-corrected).
    pub fn stddev(&self) -> f64 {
        if self.n() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var =
            self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (self.n() - 1) as f64;
        var.sqrt()
    }

    /// Smallest sample.
    pub fn min(&self) -> f64 {
        self.samples[0]
    }

    /// Largest sample.
    pub fn max(&self) -> f64 {
        self.samples[self.samples.len() - 1]
    }

    /// Median sample.
    pub fn median(&self) -> f64 {
        let s = &self.samples;
        let n = s.len();
        if n % 2 == 1 {
            s[n / 2]
        } else {
            (s[n / 2 - 1] + s[n / 2]) / 2.0
        }
    }

    /// Nearest-rank percentile, `p` in `[0, 100]` — the latency summary
    /// convention of service benchmarks (p50/p95/p99). `p = 0` is the
    /// minimum, `p = 100` the maximum. A rank lookup into the sorted
    /// samples, so `p ≤ q` implies `percentile(p) ≤ percentile(q)`.
    ///
    /// # Panics
    /// If `p` is outside `[0, 100]` or not finite.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!(p.is_finite() && (0.0..=100.0).contains(&p), "percentile {p} outside [0, 100]");
        let s = &self.samples;
        if p == 0.0 {
            return s[0];
        }
        // Nearest-rank: smallest sample with at least p% of the set at
        // or below it.
        let rank = ((p / 100.0) * s.len() as f64).ceil() as usize;
        s[rank.clamp(1, s.len()) - 1]
    }

    /// Median by nearest rank (p50) — the service-latency convention.
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    /// 95th-percentile sample.
    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    /// 99th-percentile sample.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Half-width of the 95% confidence interval on the mean
    /// (t·s/√n — the paper's error bars).
    pub fn ci95(&self) -> f64 {
        if self.n() < 2 {
            return 0.0;
        }
        t_critical_95(self.n() - 1) * self.stddev() / (self.n() as f64).sqrt()
    }

    /// `mean ± ci` rendering in a given unit.
    pub fn display_ms(&self) -> String {
        format!("{:.3} ± {:.3} ms", self.mean() / 1e3, self.ci95() / 1e3)
    }
}

/// Two-sided 95% t critical value for `df` degrees of freedom
/// (table through 30, 1.96 asymptote beyond — standard practice).
fn t_critical_95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        f64::INFINITY
    } else if df <= 30 {
        TABLE[df - 1]
    } else {
        1.96 + 2.4 / df as f64 // smooth approach to the normal quantile
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_samples() {
        let s = RunStats::new(vec![5.0; 50]);
        assert_eq!(s.mean(), 5.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.ci95(), 0.0);
        assert_eq!(s.median(), 5.0);
    }

    #[test]
    fn known_values() {
        let s = RunStats::new(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        // t(4) = 2.776; ci = 2.776·sqrt(2.5)/sqrt(5)
        let expect = 2.776 * (2.5f64).sqrt() / (5f64).sqrt();
        assert!((s.ci95() - expect).abs() < 1e-9);
    }

    #[test]
    fn paper_n50_uses_near_normal_t() {
        let t = t_critical_95(49);
        assert!(t > 1.96 && t < 2.05, "{t}");
    }

    #[test]
    fn median_even() {
        let s = RunStats::new(vec![4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.median(), 2.5);
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn empty_rejected() {
        RunStats::new(vec![]);
    }

    /// Regression: a single NaN timing sample used to survive until
    /// `median`'s `partial_cmp(..).unwrap()` and panic there, mid-sweep,
    /// without naming the culprit. It is now rejected at construction
    /// with the offending index.
    #[test]
    #[should_panic(expected = "sample 2 is not finite")]
    fn nan_sample_rejected_with_index() {
        RunStats::new(vec![1.0, 2.0, f64::NAN, 4.0]);
    }

    #[test]
    #[should_panic(expected = "not finite")]
    fn infinite_sample_rejected() {
        RunStats::new(vec![1.0, f64::INFINITY]);
    }

    #[test]
    #[should_panic(expected = "not finite")]
    fn negative_infinity_rejected() {
        RunStats::new(vec![f64::NEG_INFINITY]);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let s = RunStats::new((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p95(), 95.0);
        assert_eq!(s.p99(), 99.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn percentile_single_sample() {
        let s = RunStats::new(vec![7.0]);
        assert_eq!(s.p50(), 7.0);
        assert_eq!(s.p99(), 7.0);
    }

    #[test]
    #[should_panic(expected = "outside [0, 100]")]
    fn percentile_out_of_range_rejected() {
        RunStats::new(vec![1.0]).percentile(101.0);
    }

    /// Regression: percentiles are rank lookups into one sorted array,
    /// so the p50 ≤ p95 ≤ p99 ordering can never invert — the bug class
    /// the load harness's per-tenant summary used to be exposed to when
    /// each call re-derived its own ordering.
    #[test]
    fn percentiles_are_monotone() {
        let mut samples: Vec<f64> = (0..500).map(|i| ((i * 7919) % 977) as f64).collect();
        samples.push(0.0);
        let s = RunStats::new(samples);
        assert!(s.p50() <= s.p95(), "{} > {}", s.p50(), s.p95());
        assert!(s.p95() <= s.p99(), "{} > {}", s.p95(), s.p99());
        assert!(s.p99() <= s.max());
        assert!(s.min() <= s.p50());
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = RunStats::new((0..10).map(|i| i as f64).collect());
        let b = RunStats::new((0..100).map(|i| (i % 10) as f64).collect());
        assert!(b.ci95() < a.ci95());
    }
}
