//! CSV series output (for external plotting of the regenerated figures).

use std::io::Write;
use std::path::Path;

/// Write one CSV with a header row. Values are written with full f64
/// precision; strings are escaped only if they contain separators.
pub fn write_csv(
    path: impl AsRef<Path>,
    headers: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", headers.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|c| escape(c)).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_reads_back() {
        let dir = std::env::temp_dir().join(format!("hpxfft-csv-{}", std::process::id()));
        let path = dir.join("out.csv");
        write_csv(
            &path,
            &["x", "y"],
            &[vec!["1".into(), "2.5".into()], vec!["a,b".into(), "q\"q".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x,y\n1,2.5\n\"a,b\",\"q\"\"q\"\n");
    }
}
