//! Measurement, statistics, and reporting.
//!
//! The paper reports runtimes "averaged over 50 runs ... with 95%
//! confidence bars"; [`stats::RunStats`] implements exactly that
//! methodology (mean ± t-distribution 95% CI), [`table`] prints
//! paper-style rows, and [`csv`] dumps series for external plotting.

pub mod csv;
pub mod stats;
pub mod table;

pub use stats::RunStats;
