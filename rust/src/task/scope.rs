//! Structured data parallelism over slices (the `rayon` stand-in).
//!
//! Work is dispatched to the process-wide [`ThreadPool::global`] worker
//! pool via [`ThreadPool::run_scoped`], so closures may borrow from the
//! caller's stack — which is exactly what the batched row-FFT needs:
//! mutate a large buffer in place from `nthreads` workers without
//! `Arc`-wrapping it. Running on the shared pool (instead of spawning OS
//! threads per call, as an earlier revision did) makes concurrent
//! localities' sweeps queue onto one core-sized worker set — the
//! MPI+pthreads "+X" model with HPX's one-pool-per-process discipline.

use super::pool::ThreadPool;

/// Run `f(i)` for every `i in 0..n` across up to `nthreads` pool tasks.
///
/// Work is split into contiguous index blocks (good locality for row
/// loops). `nthreads == 1` or `n <= 1` degrades to a plain loop with zero
/// dispatch overhead.
pub fn parallel_for(n: usize, nthreads: usize, f: impl Fn(usize) + Sync) {
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let per = n.div_ceil(nthreads);
    let f = &f;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(nthreads);
    for t in 0..nthreads {
        let lo = t * per;
        let hi = ((t + 1) * per).min(n);
        if lo >= hi {
            break;
        }
        tasks.push(Box::new(move || {
            for i in lo..hi {
                f(i);
            }
        }));
    }
    ThreadPool::global().run_scoped(tasks);
}

/// Split `data` into `chunk`-sized mutable pieces and process them in
/// parallel on the global pool; `f` receives the chunk index and the
/// chunk.
pub fn parallel_chunks_mut<T: Send>(
    data: &mut [T],
    chunk: usize,
    nthreads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk > 0, "chunk size must be positive");
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let nthreads = nthreads.max(1).min(chunks.len().max(1));
    if nthreads <= 1 {
        for (i, c) in chunks {
            f(i, c);
        }
        return;
    }
    // Round-robin chunks over tasks to balance ragged tails.
    let mut buckets: Vec<Vec<(usize, &mut [T])>> = (0..nthreads).map(|_| Vec::new()).collect();
    for (k, item) in chunks.into_iter().enumerate() {
        buckets[k % nthreads].push(item);
    }
    let f = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = buckets
        .into_iter()
        .map(|bucket| {
            Box::new(move || {
                for (i, c) in bucket {
                    f(i, c);
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    ThreadPool::global().run_scoped(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(100, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_single_thread() {
        let sum = AtomicUsize::new(0);
        parallel_for(10, 1, |i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn parallel_for_zero_items() {
        parallel_for(0, 4, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_for_more_threads_than_items() {
        let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(3, 16, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn chunks_mut_writes_all() {
        let mut data = vec![0usize; 103]; // ragged tail
        parallel_chunks_mut(&mut data, 10, 4, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[102], 11); // 11th chunk (index 10)
    }

    #[test]
    fn chunks_mut_exact_division() {
        let mut data = vec![1.0f32; 64];
        parallel_chunks_mut(&mut data, 16, 2, |_, chunk| {
            for x in chunk.iter_mut() {
                *x *= 2.0;
            }
        });
        assert!(data.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn many_concurrent_callers_share_the_pool() {
        // Several OS threads (stand-ins for localities) issuing parallel
        // sweeps at once: all work lands, nothing deadlocks.
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    let mut data = vec![0u32; 64];
                    parallel_chunks_mut(&mut data, 8, 4, |_, chunk| {
                        for x in chunk.iter_mut() {
                            *x = t + 1;
                        }
                    });
                    assert!(data.iter().all(|&x| x == t + 1));
                });
            }
        });
    }
}
