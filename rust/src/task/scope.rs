//! Structured data parallelism over slices (the `rayon` stand-in).
//!
//! Built on `std::thread::scope`, so closures may borrow from the caller's
//! stack — which is exactly what the batched row-FFT needs: mutate a large
//! buffer in place from `nthreads` workers without `Arc`-wrapping it.

/// Run `f(i)` for every `i in 0..n` across `nthreads` OS threads.
///
/// Work is split into contiguous index blocks (good locality for row
/// loops). `nthreads == 1` or `n <= 1` degrades to a plain loop with zero
/// spawn overhead.
pub fn parallel_for(n: usize, nthreads: usize, f: impl Fn(usize) + Sync) {
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let per = n.div_ceil(nthreads);
    std::thread::scope(|s| {
        for t in 0..nthreads {
            let lo = t * per;
            let hi = ((t + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Split `data` into `chunk`-sized mutable pieces and process them in
/// parallel; `f` receives the chunk index and the chunk.
pub fn parallel_chunks_mut<T: Send>(
    data: &mut [T],
    chunk: usize,
    nthreads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk > 0, "chunk size must be positive");
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let nthreads = nthreads.max(1).min(chunks.len().max(1));
    if nthreads <= 1 {
        for (i, c) in chunks {
            f(i, c);
        }
        return;
    }
    // Round-robin chunks over threads to balance ragged tails.
    let mut buckets: Vec<Vec<(usize, &mut [T])>> = (0..nthreads).map(|_| Vec::new()).collect();
    for (k, item) in chunks.into_iter().enumerate() {
        buckets[k % nthreads].push(item);
    }
    std::thread::scope(|s| {
        for bucket in buckets {
            let f = &f;
            s.spawn(move || {
                for (i, c) in bucket {
                    f(i, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(100, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_single_thread() {
        let sum = AtomicUsize::new(0);
        parallel_for(10, 1, |i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn parallel_for_zero_items() {
        parallel_for(0, 4, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_for_more_threads_than_items() {
        let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(3, 16, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn chunks_mut_writes_all() {
        let mut data = vec![0usize; 103]; // ragged tail
        parallel_chunks_mut(&mut data, 10, 4, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[102], 11); // 11th chunk (index 10)
    }

    #[test]
    fn chunks_mut_exact_division() {
        let mut data = vec![1.0f32; 64];
        parallel_chunks_mut(&mut data, 16, 2, |_, chunk| {
            for x in chunk.iter_mut() {
                *x *= 2.0;
            }
        });
        assert!(data.iter().all(|&x| x == 2.0));
    }
}
