//! One-shot promise/future cells with continuations and combinators.
//!
//! Mirrors `hpx::promise` / `hpx::future`: a producer fulfils the
//! [`Promise`] exactly once; any number of consumers block on
//! [`TaskFuture::get`] (single value: first getter takes it, a cloned
//! future shares the same cell), clone the value with
//! [`TaskFuture::get_cloned`] (shared-future semantics), or attach
//! continuations:
//!
//! - [`TaskFuture::then_inline`] — runs on the fulfilling thread (HPX's
//!   `hpx::launch::sync` continuation policy);
//! - [`TaskFuture::then`] — runs on the process-wide worker pool (HPX's
//!   default `hpx::launch::async` policy), returning a future for the
//!   continuation's own result so chains compose;
//! - [`when_all_async`] / [`when_each`] — HPX's combinators:
//!   `when_all_async` assembles the nonblocking collectives' results,
//!   `when_each` streams send completions to the async FFT drivers;
//! - [`CollectiveFuture`] — the handle a nonblocking collective returns:
//!   a result future plus the per-wire-chunk send-completion futures, so
//!   callers can consume the result while the tail of the transfer is
//!   still draining (the comm/compute overlap of the async FFT variants).
//!
//! ## Reentrancy
//!
//! Continuations fire strictly *after* the value is published: the
//! fulfilling thread stores the value, drops the state lock, and only
//! then runs the queued continuations (each takes a short lock to clone
//! the value). A continuation may therefore call `get`, `get_cloned`,
//! `then_inline`, or `then` on a clone of the same future without
//! deadlocking — the regression this guards against is a continuation
//! self-deadlocking on the state mutex the old implementation held while
//! running it. While the continuations drain, consuming getters on
//! *other* threads are held back, so a racing `get` cannot starve a
//! continuation of the value; only a *reentrant* `get` from inside a
//! continuation (which proceeds immediately, by design) can consume the
//! value ahead of later continuations, in which case those are skipped.

use super::pool::ThreadPool;
// Via the loom shim: `tests/loom.rs` model-checks this cell's
// interleavings by swapping in mock primitives under `--cfg loom`.
use crate::util::sync::{Arc, Condvar, Mutex};

/// Queued continuation: self-contained, re-acquires the state lock only
/// to clone the value (never held while user code runs).
type Continuation = Box<dyn FnOnce() + Send>;

struct Shared<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
}

struct State<T> {
    value: Option<T>,
    fulfilled: bool,
    /// While `Promise::set` is running the queued continuations, the
    /// fulfilling thread's id is recorded here. Getters on *other*
    /// threads wait it out, so a consuming `get` can never race a
    /// continuation out of its value; getters on the draining thread
    /// itself (reentrant continuations) proceed immediately.
    draining: Option<std::thread::ThreadId>,
    continuations: Vec<Continuation>,
}

impl<T> State<T> {
    /// Whether a getter on the current thread may consume/observe now.
    fn readable(&self) -> bool {
        self.fulfilled
            && match self.draining {
                None => true,
                Some(id) => id == std::thread::current().id(),
            }
    }
}

/// Write side of the cell. Fulfil with [`Promise::set`].
pub struct Promise<T> {
    shared: Arc<Shared<T>>,
}

/// Read side of the cell. Cheap to clone; all clones observe the same value.
pub struct TaskFuture<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for TaskFuture<T> {
    fn clone(&self) -> Self {
        Self { shared: Arc::clone(&self.shared) }
    }
}

impl<T: Send + 'static> Promise<T> {
    /// Create a linked promise/future pair.
    pub fn new() -> (Promise<T>, TaskFuture<T>) {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                value: None,
                fulfilled: false,
                draining: None,
                continuations: Vec::new(),
            }),
            cv: Condvar::new(),
        });
        (Promise { shared: Arc::clone(&shared) }, TaskFuture { shared })
    }

    /// Fulfil the promise: publish the value, run queued continuations,
    /// wake getters.
    ///
    /// The value is stored and the state lock released *before* any
    /// continuation runs, so continuations may touch the same future
    /// (even blocking on a clone of it) without deadlocking. While the
    /// continuations drain, consuming getters on *other* threads are held
    /// back (see `State::draining`), so a racing `get` can never starve a
    /// continuation of the value.
    ///
    /// # Panics
    /// If the promise was already fulfilled (double-set is a logic error).
    pub fn set(self, value: T) {
        let continuations = {
            let mut st = self.shared.state.lock().unwrap();
            assert!(!st.fulfilled, "promise fulfilled twice");
            st.fulfilled = true;
            st.value = Some(value);
            if !st.continuations.is_empty() {
                st.draining = Some(std::thread::current().id());
            }
            std::mem::take(&mut st.continuations)
        };
        // Clear the draining mark and wake blocked getters on every exit
        // path, including a panicking continuation.
        struct FinishOnDrop<'a, T>(&'a Shared<T>);
        impl<T> Drop for FinishOnDrop<'_, T> {
            fn drop(&mut self) {
                self.0.state.lock().unwrap().draining = None;
                self.0.cv.notify_all();
            }
        }
        let _finish = FinishOnDrop(&self.shared);
        for k in continuations {
            k();
        }
    }
}

impl<T: Send + 'static> TaskFuture<T> {
    /// Construct an already-fulfilled future (HPX `make_ready_future`).
    pub fn ready(value: T) -> Self {
        let (p, f) = Promise::new();
        p.set(value);
        f
    }

    /// Block until fulfilled and take the value.
    ///
    /// # Panics
    /// If the value was already taken by another `get` on a clone.
    pub fn get(self) -> T {
        let mut st = self.shared.state.lock().unwrap();
        while !st.readable() {
            st = self.shared.cv.wait(st).unwrap();
        }
        st.value.take().expect("future value already taken")
    }

    /// Block until fulfilled; do not consume the value.
    pub fn wait(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while !st.readable() {
            st = self.shared.cv.wait(st).unwrap();
        }
    }

    /// Whether the promise has been fulfilled.
    pub fn is_ready(&self) -> bool {
        self.shared.state.lock().unwrap().fulfilled
    }
}

impl<T: Clone + Send + 'static> TaskFuture<T> {
    /// Block until fulfilled and clone the value (shared futures).
    pub fn get_cloned(&self) -> T {
        let mut st = self.shared.state.lock().unwrap();
        while !st.readable() {
            st = self.shared.cv.wait(st).unwrap();
        }
        st.value.as_ref().expect("fulfilled future lost its value").clone()
    }

    /// Attach a continuation that runs with (a clone of) the value on the
    /// fulfilling thread — or inline right now if already fulfilled. The
    /// state lock is *not* held while `k` runs, so `k` may safely touch
    /// clones of this future (reentrancy, see the module docs).
    ///
    /// A continuation registered *after* a consuming `get` already took
    /// the value is skipped: the consumption happened-before the
    /// registration, so there is no value left to observe.
    pub fn then_inline(&self, k: impl FnOnce(&T) + Send + 'static) {
        let ready = {
            let mut st = self.shared.state.lock().unwrap();
            if st.fulfilled {
                // Clone under this lock: a consuming `get` on another
                // thread cannot race the value away between here and
                // running `k` below.
                st.value.clone()
            } else {
                let shared = Arc::clone(&self.shared);
                st.continuations.push(Box::new(move || {
                    // Queued path: cross-thread getters are held back
                    // while continuations drain, so the value can only
                    // be missing if a reentrant get on the draining
                    // thread consumed it — then later continuations are
                    // skipped (documented above).
                    let value = shared.state.lock().unwrap().value.clone();
                    if let Some(v) = value {
                        k(&v);
                    }
                }));
                return;
            }
        };
        if let Some(v) = ready {
            k(&v);
        }
    }

    /// Chain a continuation launched on the process-wide worker pool
    /// (HPX `future::then` with the default async launch policy): when
    /// this future is fulfilled, `f` runs on a [`ThreadPool::global`]
    /// worker with a clone of the value, and the returned future carries
    /// `f`'s result. Because the continuation runs on the pool, it may
    /// block (even on collectives) without stalling the fulfilling
    /// thread.
    pub fn then<U: Send + 'static>(
        &self,
        f: impl FnOnce(T) -> U + Send + 'static,
    ) -> TaskFuture<U> {
        let (p, out) = Promise::new();
        self.then_inline(move |v: &T| {
            let v = v.clone();
            let _spawned = ThreadPool::global().spawn(move || p.set(f(v)));
        });
        out
    }
}

/// Wait for all futures, collecting values in order (blocking
/// `hpx::when_all(...).get()` shorthand).
pub fn when_all<T: Send + 'static>(futures: Vec<TaskFuture<T>>) -> Vec<T> {
    futures.into_iter().map(|f| f.get()).collect()
}

type WhenAllState<T> = Mutex<(Vec<Option<T>>, usize, Option<Promise<Vec<T>>>)>;

/// Combine futures into one future of all values, in input order, without
/// blocking (HPX `when_all`): the result is fulfilled on whichever thread
/// delivers the last input.
pub fn when_all_async<T: Clone + Send + 'static>(
    futures: Vec<TaskFuture<T>>,
) -> TaskFuture<Vec<T>> {
    let n = futures.len();
    let (p, out) = Promise::new();
    if n == 0 {
        p.set(Vec::new());
        return out;
    }
    let state: Arc<WhenAllState<T>> =
        Arc::new(Mutex::new(((0..n).map(|_| None).collect(), 0, Some(p))));
    for (i, f) in futures.iter().enumerate() {
        let state = Arc::clone(&state);
        f.then_inline(move |v: &T| {
            let done = {
                let mut st = state.lock().unwrap();
                st.0[i] = Some(v.clone());
                st.1 += 1;
                if st.1 == n {
                    let promise = st.2.take().expect("when_all fulfilled twice");
                    let values =
                        st.0.iter_mut().map(|s| s.take().expect("slot filled")).collect();
                    Some((promise, values))
                } else {
                    None
                }
            };
            if let Some((promise, values)) = done {
                promise.set(values);
            }
        });
    }
    out
}

type WhenEachState<F> = Mutex<(F, usize, Option<Promise<()>>)>;

/// Run `f(index, &value)` for every future *in completion order* — not
/// input order — as each is fulfilled (HPX `when_each`). The returned
/// future is fulfilled once every input has been seen. The callback runs
/// on whichever thread fulfils each input; calls are serialized.
pub fn when_each<T: Clone + Send + 'static>(
    futures: Vec<TaskFuture<T>>,
    f: impl FnMut(usize, &T) + Send + 'static,
) -> TaskFuture<()> {
    let n = futures.len();
    let (p, out) = Promise::new();
    if n == 0 {
        p.set(());
        return out;
    }
    let state: Arc<WhenEachState<_>> = Arc::new(Mutex::new((f, 0usize, Some(p))));
    for (i, fut) in futures.iter().enumerate() {
        let state = Arc::clone(&state);
        fut.then_inline(move |v: &T| {
            let done = {
                let mut st = state.lock().unwrap();
                (st.0)(i, v);
                st.1 += 1;
                if st.1 == n {
                    st.2.take()
                } else {
                    None
                }
            };
            if let Some(promise) = done {
                promise.set(());
            }
        });
    }
    out
}

/// Handle returned by the nonblocking collectives
/// ([`crate::collectives::Communicator::all_to_all_async`] and friends):
/// a future for the collective's *result* (delivered data) plus one
/// completion future per posted wire chunk on the send side.
///
/// The split is the overlap hook: the result becomes ready as soon as
/// this rank's *receives* are in, typically while its own outgoing
/// chunks are still draining through the send pool — a caller can start
/// computing on the result (the async FFT variants run the whole
/// second-dimension FFT there) and settle the sends afterwards.
pub struct CollectiveFuture<T> {
    result: TaskFuture<T>,
    chunk_sends: Vec<TaskFuture<()>>,
}

impl<T: Send + 'static> CollectiveFuture<T> {
    /// Bundle a result future with its per-chunk send completions.
    pub fn new(result: TaskFuture<T>, chunk_sends: Vec<TaskFuture<()>>) -> Self {
        Self { result, chunk_sends }
    }

    /// A collective that completed at posting time (no wire traffic).
    pub fn ready(value: T) -> Self {
        Self { result: TaskFuture::ready(value), chunk_sends: Vec::new() }
    }

    /// The result future (receive side).
    pub fn result(&self) -> &TaskFuture<T> {
        &self.result
    }

    /// Per-wire-chunk send-completion futures (send side).
    pub fn chunk_sends(&self) -> &[TaskFuture<()>] {
        &self.chunk_sends
    }

    /// Whether the result (receive side) is ready.
    pub fn is_ready(&self) -> bool {
        self.result.is_ready()
    }

    /// Block until result *and* every chunk send have completed.
    pub fn wait(&self) {
        self.result.wait();
        for s in &self.chunk_sends {
            s.wait();
        }
    }

    /// Blocking completion: take the result, then settle every chunk
    /// send. This is exactly what the blocking collective wrappers do.
    pub fn get(self) -> T {
        let value = self.result.get();
        for s in self.chunk_sends {
            s.get();
        }
        value
    }

    /// Split into the result future and the send completions — the
    /// overlap-hungry path: consume the result now, settle sends later.
    pub fn into_parts(self) -> (TaskFuture<T>, Vec<TaskFuture<()>>) {
        (self.result, self.chunk_sends)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;
    use std::time::Duration;

    #[test]
    fn set_then_get() {
        let (p, f) = Promise::new();
        p.set(42);
        assert_eq!(f.get(), 42);
    }

    #[test]
    fn get_blocks_until_set() {
        let (p, f) = Promise::new();
        let h = thread::spawn(move || f.get());
        thread::sleep(Duration::from_millis(20));
        p.set("done");
        assert_eq!(h.join().unwrap(), "done");
    }

    #[test]
    fn ready_future_is_ready() {
        let f = TaskFuture::ready(7u32);
        assert!(f.is_ready());
        assert_eq!(f.get(), 7);
    }

    #[test]
    fn continuation_runs_on_set() {
        let (p, f) = Promise::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        f.then_inline(move |&v: &usize| {
            assert_eq!(v, 5);
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 0);
        p.set(5);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn continuation_after_set_runs_immediately() {
        let f = TaskFuture::ready(1u8);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        f.then_inline(move |_| {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn reentrant_continuation_does_not_deadlock() {
        // The Promise::set regression: a continuation that blocks on (or
        // re-registers with) a clone of the same future must not deadlock
        // on the state mutex the old implementation held while running
        // continuations.
        let (p, f) = Promise::new();
        let clone_for_get = f.clone();
        let clone_for_then = f.clone();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        f.then_inline(move |&v: &u32| {
            // Reentrant consuming get on a clone of the same future.
            assert_eq!(clone_for_get.get_cloned(), v);
            // Reentrant continuation registration (already fulfilled →
            // runs inline, also under no lock).
            let h2 = Arc::clone(&h);
            clone_for_then.then_inline(move |&w: &u32| {
                assert_eq!(w, 9);
                h2.fetch_add(1, Ordering::SeqCst);
            });
            h.fetch_add(1, Ordering::SeqCst);
        });
        p.set(9);
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        assert_eq!(f.get(), 9, "value still consumable after continuations");
    }

    #[test]
    fn consuming_get_waits_for_continuations() {
        // A getter racing Promise::set must not starve a slow
        // continuation of the value: cross-thread gets are held back
        // until the continuations have drained.
        let (p, f) = Promise::new();
        let observed = Arc::new(AtomicUsize::new(0));
        let o = Arc::clone(&observed);
        f.then_inline(move |&v: &usize| {
            thread::sleep(Duration::from_millis(20));
            o.store(v, Ordering::SeqCst);
        });
        let getter = {
            let f2 = f.clone();
            thread::spawn(move || f2.get())
        };
        thread::sleep(Duration::from_millis(5));
        p.set(7);
        assert_eq!(getter.join().unwrap(), 7);
        assert_eq!(
            observed.load(Ordering::SeqCst),
            7,
            "continuation must observe the value despite the racing get"
        );
    }

    #[test]
    fn reentrant_blocking_get_from_continuation() {
        let (p, f) = Promise::new();
        let clone = f.clone();
        let (done_p, done_f) = Promise::new();
        let mut done_p = Some(done_p);
        f.then_inline(move |_: &u8| {
            // Blocking get on a clone: value is already published.
            let v = clone.get();
            done_p.take().unwrap().set(v);
        });
        p.set(3);
        assert_eq!(done_f.get(), 3);
    }

    #[test]
    fn then_chains_on_pool() {
        let (p, f) = Promise::new();
        let doubled = f.then(|v: usize| v * 2);
        let plus_one = doubled.then(|v| v + 1);
        p.set(20);
        assert_eq!(plus_one.get(), 41);
        assert_eq!(f.get(), 20, "source value untouched by then chain");
    }

    #[test]
    fn then_on_ready_future_still_runs() {
        let f = TaskFuture::ready(5u64);
        assert_eq!(f.then(|v| v + 1).get(), 6);
    }

    #[test]
    fn then_continuation_may_block() {
        // The pool-launched continuation blocks on another future —
        // legal, because it does not run on the fulfilling thread.
        let (pa, fa) = Promise::new();
        let (pb, fb) = Promise::<u32>::new();
        let sum = fa.then(move |a: u32| a + fb.get());
        pa.set(1);
        thread::sleep(Duration::from_millis(5));
        pb.set(2);
        assert_eq!(sum.get(), 3);
    }

    #[test]
    fn when_all_preserves_order() {
        let pairs: Vec<_> = (0..8).map(|_| Promise::<usize>::new()).collect();
        let (promises, futures): (Vec<_>, Vec<_>) = pairs.into_iter().unzip();
        // Fulfil in reverse order on another thread.
        let h = thread::spawn(move || {
            for (i, p) in promises.into_iter().enumerate().rev() {
                p.set(i * 10);
            }
        });
        let vals = when_all(futures);
        h.join().unwrap();
        assert_eq!(vals, (0..8).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn when_all_async_is_nonblocking_and_ordered() {
        let pairs: Vec<_> = (0..6).map(|_| Promise::<usize>::new()).collect();
        let (promises, futures): (Vec<_>, Vec<_>) = pairs.into_iter().unzip();
        let all = when_all_async(futures);
        assert!(!all.is_ready(), "must not block at combine time");
        for (i, p) in promises.into_iter().enumerate().rev() {
            p.set(i + 100);
        }
        assert_eq!(all.get(), (0..6).map(|i| i + 100).collect::<Vec<_>>());
    }

    #[test]
    fn when_all_async_empty() {
        assert_eq!(when_all_async(Vec::<TaskFuture<u8>>::new()).get(), Vec::<u8>::new());
    }

    #[test]
    fn when_each_fires_in_completion_order() {
        let pairs: Vec<_> = (0..4).map(|_| Promise::<usize>::new()).collect();
        let (promises, futures): (Vec<_>, Vec<_>) = pairs.into_iter().unzip();
        let mut promises: Vec<Option<Promise<usize>>> =
            promises.into_iter().map(Some).collect();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s = Arc::clone(&seen);
        let done = when_each(futures, move |i, &v| s.lock().unwrap().push((i, v)));
        // Fulfil 2, 0, 3, 1.
        for idx in [2usize, 0, 3, 1] {
            promises[idx].take().unwrap().set(idx * 11);
        }
        done.get();
        assert_eq!(
            *seen.lock().unwrap(),
            vec![(2, 22), (0, 0), (3, 33), (1, 11)],
            "completion order, not input order"
        );
    }

    #[test]
    fn collective_future_get_drains_sends() {
        let (p, f) = Promise::new();
        let sent = Arc::new(AtomicUsize::new(0));
        let sends: Vec<TaskFuture<()>> = (0..3)
            .map(|_| {
                let (sp, sf) = Promise::new();
                let s = Arc::clone(&sent);
                // Fulfil the "send" from another thread after a delay.
                thread::spawn(move || {
                    thread::sleep(Duration::from_millis(5));
                    s.fetch_add(1, Ordering::SeqCst);
                    sp.set(());
                });
                sf
            })
            .collect();
        let coll = CollectiveFuture::new(f, sends);
        assert_eq!(coll.chunk_sends().len(), 3);
        p.set(77u32);
        assert!(coll.is_ready());
        assert_eq!(coll.get(), 77);
        assert_eq!(sent.load(Ordering::SeqCst), 3, "get() settles every chunk send");
    }

    #[test]
    fn collective_future_ready_and_parts() {
        let coll = CollectiveFuture::ready(vec![1u8, 2]);
        assert!(coll.is_ready());
        let (result, sends) = coll.into_parts();
        assert!(sends.is_empty());
        assert_eq!(result.get(), vec![1, 2]);
    }

    #[test]
    fn get_cloned_shares() {
        let (p, f) = Promise::new();
        let f2 = f.clone();
        p.set(vec![1, 2, 3]);
        assert_eq!(f.get_cloned(), vec![1, 2, 3]);
        assert_eq!(f2.get_cloned(), vec![1, 2, 3]);
    }

    #[test]
    fn wait_does_not_consume() {
        let (p, f) = Promise::new();
        p.set(9);
        f.wait();
        assert_eq!(f.get(), 9);
    }
}
