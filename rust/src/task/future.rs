//! One-shot promise/future cells with continuations.
//!
//! Mirrors `hpx::promise` / `hpx::future`: a producer fulfils the
//! [`Promise`] exactly once; any number of consumers block on
//! [`TaskFuture::get`] (single value: first getter takes it, a cloned
//! future shares the same cell) or attach a continuation with
//! [`TaskFuture::then_inline`]. Continuations run inline on the fulfilling
//! thread — the same semantics as HPX's `hpx::launch::sync` continuation
//! policy, which is what the FFT scatter variant relies on to transpose a
//! chunk "as soon as it is received".

use std::sync::{Arc, Condvar, Mutex};

type Continuation<T> = Box<dyn FnOnce(&T) + Send>;

struct Shared<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
}

struct State<T> {
    value: Option<T>,
    fulfilled: bool,
    continuations: Vec<Continuation<T>>,
}

/// Write side of the cell. Fulfil with [`Promise::set`].
pub struct Promise<T> {
    shared: Arc<Shared<T>>,
}

/// Read side of the cell. Cheap to clone; all clones observe the same value.
pub struct TaskFuture<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for TaskFuture<T> {
    fn clone(&self) -> Self {
        Self { shared: Arc::clone(&self.shared) }
    }
}

impl<T: Send + 'static> Promise<T> {
    /// Create a linked promise/future pair.
    pub fn new() -> (Promise<T>, TaskFuture<T>) {
        let shared = Arc::new(Shared {
            state: Mutex::new(State { value: None, fulfilled: false, continuations: Vec::new() }),
            cv: Condvar::new(),
        });
        (Promise { shared: Arc::clone(&shared) }, TaskFuture { shared })
    }

    /// Fulfil the promise. Runs queued continuations inline, then wakes
    /// blocked getters.
    ///
    /// # Panics
    /// If the promise was already fulfilled (double-set is a logic error).
    pub fn set(self, value: T) {
        let continuations = {
            let mut st = self.shared.state.lock().unwrap();
            assert!(!st.fulfilled, "promise fulfilled twice");
            st.fulfilled = true;
            st.value = Some(value);
            std::mem::take(&mut st.continuations)
        };
        if !continuations.is_empty() {
            let st = self.shared.state.lock().unwrap();
            let value_ref = st.value.as_ref().expect("value just set");
            for k in continuations {
                k(value_ref);
            }
        }
        self.shared.cv.notify_all();
    }
}

impl<T: Send + 'static> TaskFuture<T> {
    /// Construct an already-fulfilled future (HPX `make_ready_future`).
    pub fn ready(value: T) -> Self {
        let (p, f) = Promise::new();
        p.set(value);
        f
    }

    /// Block until fulfilled and take the value.
    ///
    /// # Panics
    /// If the value was already taken by another `get` on a clone.
    pub fn get(self) -> T {
        let mut st = self.shared.state.lock().unwrap();
        while !st.fulfilled {
            st = self.shared.cv.wait(st).unwrap();
        }
        st.value.take().expect("future value already taken")
    }

    /// Block until fulfilled; do not consume the value.
    pub fn wait(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while !st.fulfilled {
            st = self.shared.cv.wait(st).unwrap();
        }
    }

    /// Whether the promise has been fulfilled.
    pub fn is_ready(&self) -> bool {
        self.shared.state.lock().unwrap().fulfilled
    }

    /// Attach a continuation that runs with a reference to the value, on
    /// the fulfilling thread (or inline right now if already fulfilled).
    pub fn then_inline(&self, k: impl FnOnce(&T) + Send + 'static) {
        let mut st = self.shared.state.lock().unwrap();
        if st.fulfilled {
            let value_ref = st.value.as_ref().expect("fulfilled future lost its value");
            k(value_ref);
        } else {
            st.continuations.push(Box::new(k));
        }
    }
}

impl<T: Clone + Send + 'static> TaskFuture<T> {
    /// Block until fulfilled and clone the value (shared futures).
    pub fn get_cloned(&self) -> T {
        let mut st = self.shared.state.lock().unwrap();
        while !st.fulfilled {
            st = self.shared.cv.wait(st).unwrap();
        }
        st.value.as_ref().expect("fulfilled future lost its value").clone()
    }
}

/// Wait for all futures, collecting values in order (HPX `when_all`).
pub fn when_all<T: Send + 'static>(futures: Vec<TaskFuture<T>>) -> Vec<T> {
    futures.into_iter().map(|f| f.get()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;
    use std::time::Duration;

    #[test]
    fn set_then_get() {
        let (p, f) = Promise::new();
        p.set(42);
        assert_eq!(f.get(), 42);
    }

    #[test]
    fn get_blocks_until_set() {
        let (p, f) = Promise::new();
        let h = thread::spawn(move || f.get());
        thread::sleep(Duration::from_millis(20));
        p.set("done");
        assert_eq!(h.join().unwrap(), "done");
    }

    #[test]
    fn ready_future_is_ready() {
        let f = TaskFuture::ready(7u32);
        assert!(f.is_ready());
        assert_eq!(f.get(), 7);
    }

    #[test]
    fn continuation_runs_on_set() {
        let (p, f) = Promise::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        f.then_inline(move |&v: &usize| {
            assert_eq!(v, 5);
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 0);
        p.set(5);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn continuation_after_set_runs_immediately() {
        let f = TaskFuture::ready(1u8);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        f.then_inline(move |_| {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn when_all_preserves_order() {
        let pairs: Vec<_> = (0..8).map(|_| Promise::<usize>::new()).collect();
        let (promises, futures): (Vec<_>, Vec<_>) = pairs.into_iter().unzip();
        // Fulfil in reverse order on another thread.
        let h = thread::spawn(move || {
            for (i, p) in promises.into_iter().enumerate().rev() {
                p.set(i * 10);
            }
        });
        let vals = when_all(futures);
        h.join().unwrap();
        assert_eq!(vals, (0..8).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn get_cloned_shares() {
        let (p, f) = Promise::new();
        let f2 = f.clone();
        p.set(vec![1, 2, 3]);
        assert_eq!(f.get_cloned(), vec![1, 2, 3]);
        assert_eq!(f2.get_cloned(), vec![1, 2, 3]);
    }

    #[test]
    fn wait_does_not_consume() {
        let (p, f) = Promise::new();
        p.set(9);
        f.wait();
        assert_eq!(f.get(), 9);
    }
}
