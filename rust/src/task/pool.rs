//! Fixed-size worker pool executing boxed tasks from a shared queue.
//!
//! This is the executor under the futurized-task model: `spawn` hands a
//! closure to the pool and returns a [`TaskFuture`] for its result. The
//! pool is deliberately simple (single injector queue + condvar) — at the
//! message/chunk granularity of the FFT benchmark the queue is never the
//! bottleneck (verified in `benches/hotpath.rs`).
//!
//! Two executors share this type: per-communicator chunk-send pools, and
//! the process-wide [`ThreadPool::global`] pool the batched row-FFT
//! sweeps run on ([`ThreadPool::run_scoped`] — the HPX-style "one worker
//! pool per process" model, instead of spawning OS threads per sweep).

use super::future::{Promise, TaskFuture};
// Via the loom shim: `tests/loom.rs` model-checks the queue/worker
// interleavings by swapping in mock primitives under `--cfg loom`.
use crate::util::sync::{thread, Arc, Condvar, Mutex};
use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::OnceLock;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Set for the lifetime of every pool worker thread (any pool).
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is a worker of *some* [`ThreadPool`].
/// [`ThreadPool::run_scoped`] uses this to degrade to inline execution
/// rather than risk a blocked-worker deadlock on nested scopes.
pub fn is_worker_thread() -> bool {
    IS_POOL_WORKER.with(|f| f.get())
}

struct Queue {
    jobs: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    pending: VecDeque<Job>,
    shutdown: bool,
}

/// A fixed pool of worker threads.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (≥ 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState { pending: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let q = Arc::clone(&queue);
                thread::Builder::new()
                    .name(format!("hpx-worker-{i}"))
                    .spawn(move || worker_loop(&q))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        Self { queue, workers, size }
    }

    /// Pool sized to the available parallelism (HPX default: one worker
    /// per core).
    pub fn with_default_parallelism() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// The process-wide compute pool (one worker per core, spawned on
    /// first use, never torn down). All batched row-FFT sweeps share it —
    /// concurrent localities enqueue their bands here instead of each
    /// spawning OS threads, the same discipline as HPX's single worker
    /// pool per process.
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(ThreadPool::with_default_parallelism)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a task; returns a future for its result.
    pub fn spawn<T: Send + 'static>(
        &self,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> TaskFuture<T> {
        let (promise, future) = Promise::new();
        let job: Job = Box::new(move || promise.set(f()));
        {
            let mut st = self.queue.jobs.lock().unwrap();
            assert!(!st.shutdown, "spawn on shut-down pool");
            st.pending.push_back(job);
        }
        self.queue.cv.notify_one();
        future
    }

    /// Run a batch of borrowing tasks to completion on the pool —
    /// structured (scoped) parallelism, the pool-backed analog of
    /// `std::thread::scope`.
    ///
    /// Every task is executed before this returns, so the tasks may
    /// borrow from the caller's stack (`'env`). Panics inside a task are
    /// caught on the worker (keeping the pool alive) and re-raised here
    /// after all tasks have settled. When called *from* a pool worker
    /// thread the tasks run inline instead of being enqueued: a worker
    /// blocking on sub-tasks of its own pool could deadlock a saturated
    /// queue.
    pub fn run_scoped<'env>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        if tasks.is_empty() {
            return;
        }
        if is_worker_thread() {
            for task in tasks {
                task();
            }
            return;
        }
        // Join-on-drop guard: every future pushed here is waited on even
        // if this frame unwinds mid-way (e.g. a later `spawn` panics on a
        // shut-down pool). Enqueued jobs always run — workers drain the
        // queue before honoring shutdown — so the waits terminate, and no
        // borrowed task can outlive the caller's frame on any path.
        struct JoinOnDrop {
            futures: Vec<TaskFuture<Result<(), Box<dyn Any + Send>>>>,
        }
        impl Drop for JoinOnDrop {
            fn drop(&mut self) {
                for future in self.futures.drain(..) {
                    future.wait();
                }
            }
        }

        let mut guard = JoinOnDrop { futures: Vec::new() };
        for task in tasks {
            // SAFETY: the only thing erased is the `'env` lifetime. Every
            // enqueued task is joined before this frame is left — by the
            // get() loop on the normal path, by `guard`'s Drop on unwind —
            // so no task (or its captured borrows) outlives the caller's
            // stack frame, the same guarantee `std::thread::scope`
            // provides structurally.
            let task: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(task) };
            guard.futures.push(self.spawn(move || catch_unwind(AssertUnwindSafe(task))));
        }
        // Settle every task before collecting results: after this loop
        // no spawned task is still running, so even if result collection
        // unwinds, no borrowed task can execute past the caller's frame.
        // (Draining while collecting would let `Drain`'s destructor
        // discard unjoined futures on unwind.)
        for future in &guard.futures {
            future.wait();
        }
        let futures = std::mem::take(&mut guard.futures);
        let mut first_panic: Option<Box<dyn Any + Send>> = None;
        for future in futures {
            if let Err(payload) = future.get() {
                first_panic.get_or_insert(payload);
            }
        }
        if let Some(payload) = first_panic {
            resume_unwind(payload);
        }
    }

    /// Submit a batch and wait for all results, in order.
    pub fn map<T: Send + 'static, I>(
        &self,
        inputs: Vec<I>,
        f: impl Fn(I) -> T + Send + Sync + 'static,
    ) -> Vec<T>
    where
        I: Send + 'static,
    {
        let f = Arc::new(f);
        let futures: Vec<_> = inputs
            .into_iter()
            .map(|input| {
                let f = Arc::clone(&f);
                self.spawn(move || f(input))
            })
            .collect();
        super::future::when_all(futures)
    }
}

fn worker_loop(queue: &Queue) {
    IS_POOL_WORKER.with(|f| f.set(true));
    loop {
        let job = {
            let mut st = queue.jobs.lock().unwrap();
            loop {
                if let Some(job) = st.pending.pop_front() {
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = queue.cv.wait(st).unwrap();
            }
        };
        job();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.jobs.lock().unwrap().shutdown = true;
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn spawn_returns_result() {
        let pool = ThreadPool::new(2);
        let f = pool.spawn(|| 2 + 2);
        assert_eq!(f.get(), 4);
    }

    #[test]
    fn many_tasks_all_run() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let futures: Vec<_> = (0..200)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.spawn(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for f in futures {
            f.get();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 200);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |i: usize| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let f = pool.spawn(|| 1);
        drop(pool); // must not hang
        assert_eq!(f.get(), 1);
    }

    #[test]
    fn pool_size_min_one() {
        assert_eq!(ThreadPool::new(0).size(), 1);
    }

    #[test]
    fn run_scoped_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0usize; 97];
        {
            let bands: Vec<&mut [usize]> = data.chunks_mut(10).collect();
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = bands
                .into_iter()
                .enumerate()
                .map(|(i, band)| {
                    Box::new(move || {
                        for x in band.iter_mut() {
                            *x = i + 1;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[96], 10);
    }

    #[test]
    fn run_scoped_propagates_panic_and_keeps_pool_alive() {
        let pool = ThreadPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_scoped(vec![
                Box::new(|| {}) as Box<dyn FnOnce() + Send>,
                Box::new(|| panic!("task boom")),
            ]);
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        // The workers must have survived the caught panic.
        assert_eq!(pool.spawn(|| 5).get(), 5);
    }

    #[test]
    fn run_scoped_from_worker_runs_inline() {
        // A pool task invoking run_scoped on its own pool must not
        // deadlock even when every worker is busy.
        let pool = Arc::new(ThreadPool::new(1));
        let p2 = Arc::clone(&pool);
        let f = pool.spawn(move || {
            let counter = AtomicUsize::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            p2.run_scoped(tasks);
            counter.load(Ordering::SeqCst)
        });
        assert_eq!(f.get(), 4);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = ThreadPool::global();
        let b = ThreadPool::global();
        assert!(std::ptr::eq(a, b));
        assert!(a.size() >= 1);
    }

    #[test]
    fn nested_spawn_does_not_deadlock() {
        // A task spawning another task and waiting on it must complete as
        // long as the pool has ≥ 2 workers.
        let pool = Arc::new(ThreadPool::new(2));
        let p2 = Arc::clone(&pool);
        let f = pool.spawn(move || p2.spawn(|| 21).get() * 2);
        assert_eq!(f.get(), 42);
    }
}
