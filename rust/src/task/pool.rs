//! Fixed-size worker pool executing boxed tasks from a shared queue.
//!
//! This is the executor under the futurized-task model: `spawn` hands a
//! closure to the pool and returns a [`TaskFuture`] for its result. The
//! pool is deliberately simple (single injector queue + condvar) — at the
//! message/chunk granularity of the FFT benchmark the queue is never the
//! bottleneck (verified in `benches/hotpath.rs`).

use super::future::{Promise, TaskFuture};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    pending: VecDeque<Job>,
    shutdown: bool,
}

/// A fixed pool of worker threads.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (≥ 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new(QueueState { pending: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("hpx-worker-{i}"))
                    .spawn(move || worker_loop(&q))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        Self { queue, workers, size }
    }

    /// Pool sized to the available parallelism (HPX default: one worker
    /// per core).
    pub fn with_default_parallelism() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a task; returns a future for its result.
    pub fn spawn<T: Send + 'static>(
        &self,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> TaskFuture<T> {
        let (promise, future) = Promise::new();
        let job: Job = Box::new(move || promise.set(f()));
        {
            let mut st = self.queue.jobs.lock().unwrap();
            assert!(!st.shutdown, "spawn on shut-down pool");
            st.pending.push_back(job);
        }
        self.queue.cv.notify_one();
        future
    }

    /// Submit a batch and wait for all results, in order.
    pub fn map<T: Send + 'static, I>(
        &self,
        inputs: Vec<I>,
        f: impl Fn(I) -> T + Send + Sync + 'static,
    ) -> Vec<T>
    where
        I: Send + 'static,
    {
        let f = Arc::new(f);
        let futures: Vec<_> = inputs
            .into_iter()
            .map(|input| {
                let f = Arc::clone(&f);
                self.spawn(move || f(input))
            })
            .collect();
        super::future::when_all(futures)
    }
}

fn worker_loop(queue: &Queue) {
    loop {
        let job = {
            let mut st = queue.jobs.lock().unwrap();
            loop {
                if let Some(job) = st.pending.pop_front() {
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = queue.cv.wait(st).unwrap();
            }
        };
        job();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.jobs.lock().unwrap().shutdown = true;
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn spawn_returns_result() {
        let pool = ThreadPool::new(2);
        let f = pool.spawn(|| 2 + 2);
        assert_eq!(f.get(), 4);
    }

    #[test]
    fn many_tasks_all_run() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let futures: Vec<_> = (0..200)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.spawn(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for f in futures {
            f.get();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 200);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |i: usize| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let f = pool.spawn(|| 1);
        drop(pool); // must not hang
        assert_eq!(f.get(), 1);
    }

    #[test]
    fn pool_size_min_one() {
        assert_eq!(ThreadPool::new(0).size(), 1);
    }

    #[test]
    fn nested_spawn_does_not_deadlock() {
        // A task spawning another task and waiting on it must complete as
        // long as the pool has ≥ 2 workers.
        let pool = Arc::new(ThreadPool::new(2));
        let p2 = Arc::clone(&pool);
        let f = pool.spawn(move || p2.spawn(|| 21).get() * 2);
        assert_eq!(f.get(), 42);
    }
}
