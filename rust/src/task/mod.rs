//! Futurized task runtime — the HPX-analog asynchronous many-task substrate.
//!
//! HPX parallelizes with lightweight tasks returning futures; this module
//! provides the same model on OS threads: a [`ThreadPool`] executor
//! (including the process-wide [`ThreadPool::global`] compute pool and
//! the scoped borrowing batches of [`ThreadPool::run_scoped`]),
//! [`Promise`]/[`TaskFuture`] one-shot synchronization cells with
//! continuation support ([`TaskFuture::then_inline`] sync-launched,
//! [`TaskFuture::then`] pool-launched), combinators ([`when_all`],
//! [`when_all_async`], [`when_each`]), the [`CollectiveFuture`] handle
//! the nonblocking collectives return, and data-parallel helpers
//! ([`parallel_for`], [`parallel_chunks_mut`]) that stand in for
//! `hpx::for_each(par, ...)` (and for `rayon`, which is unavailable in
//! this offline build).

mod future;
mod pool;
mod scope;

pub use future::{when_all, when_all_async, when_each, CollectiveFuture, Promise, TaskFuture};
pub use pool::{is_worker_thread, ThreadPool};
pub use scope::{parallel_chunks_mut, parallel_for};
