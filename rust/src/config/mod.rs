//! Configuration: cluster presets (the paper's Fig. 2) and benchmark run
//! matrices, with a minimal key=value config-file loader.

pub mod bench;
pub mod cluster;
pub mod kv;
pub mod spec;

pub use bench::BenchConfig;
pub use cluster::ClusterSpec;
pub use spec::TransformSpec;
