//! Minimal `key = value` config-file format (the offline stand-in for a
//! TOML dependency): comments with `#`, sections with `[name]` flattened
//! into dotted keys, everything else `key = value`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed config: dotted keys → raw string values.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse config text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {raw:?}", lineno + 1);
            };
            let key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            if values.insert(key.clone(), value.trim().to_string()).is_some() {
                bail!("line {}: duplicate key {key:?}", lineno + 1);
            }
        }
        Ok(Self { values })
    }

    /// Load and parse a config file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Raw value of a dotted key, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed value of a dotted key, if present.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.values.get(key) {
            None => Ok(None),
            Some(raw) => raw
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("config key {key} = {raw:?}: {e}")),
        }
    }

    /// All dotted keys, sorted.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let cfg = Config::parse(
            "# top comment\nrows = 256\n[bench]\nreps = 50  # inline\nport = lci\n",
        )
        .unwrap();
        assert_eq!(cfg.get("rows"), Some("256"));
        assert_eq!(cfg.get("bench.reps"), Some("50"));
        assert_eq!(cfg.get("bench.port"), Some("lci"));
    }

    #[test]
    fn typed_access() {
        let cfg = Config::parse("n = 42\nratio = 2.5\n").unwrap();
        assert_eq!(cfg.get_parsed::<usize>("n").unwrap(), Some(42));
        assert_eq!(cfg.get_parsed::<f64>("ratio").unwrap(), Some(2.5));
        assert_eq!(cfg.get_parsed::<usize>("absent").unwrap(), None);
        assert!(cfg.get_parsed::<usize>("ratio").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("this is not kv\n").is_err());
    }

    #[test]
    fn rejects_duplicates() {
        assert!(Config::parse("a = 1\na = 2\n").is_err());
    }
}
