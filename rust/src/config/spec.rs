//! `TransformSpec` — the execution settings shared by every transform
//! shape.
//!
//! [`DistFftConfig`], [`Pencil3Config`], and [`BenchConfig`] historically
//! each carried their own copy of the same eight knobs (port, chunk
//! policy, execution mode, domain, threads, wire model, engine, verify).
//! `TransformSpec` is the merged form: the CLI and the key=value config
//! files parse into it once, [`TransformRequest`] consumes it, and the
//! shape-specific configs convert to/from it
//! ([`DistFftConfig::spec`]/[`DistFftConfig::apply_spec`] and the
//! pencil equivalents).
//!
//! [`DistFftConfig`]: crate::dist_fft::DistFftConfig
//! [`DistFftConfig::spec`]: crate::dist_fft::DistFftConfig::spec
//! [`DistFftConfig::apply_spec`]: crate::dist_fft::DistFftConfig::apply_spec
//! [`Pencil3Config`]: crate::dist_fft::Pencil3Config
//! [`BenchConfig`]: super::BenchConfig
//! [`TransformRequest`]: crate::dist_fft::TransformRequest

use super::kv::Config;
use crate::collectives::ChunkPolicy;
use crate::dist_fft::driver::{ComputeEngine, Domain, ExecutionMode};
use crate::parcelport::{NetModel, PortKind};
use anyhow::Result;

/// Execution settings shared by 2-D slab, 3-D pencil, and service
/// transforms — everything about a run except its shape.
#[derive(Clone, Debug)]
pub struct TransformSpec {
    /// Parcelport backend.
    pub port: PortKind,
    /// Wire-chunking policy installed on the run's communicators.
    pub chunk: ChunkPolicy,
    /// Lock-step blocking collectives vs the future-chained task graph.
    pub exec: ExecutionMode,
    /// Input domain: complex (c2c) or real (r2c, halved wire bytes).
    pub domain: Domain,
    /// Worker threads per locality for the row-FFT phases.
    pub threads_per_locality: usize,
    /// Optional hybrid wire model.
    pub net: Option<NetModel>,
    /// Row-FFT compute engine.
    pub engine: ComputeEngine,
    /// Compare the distributed result against the serial reference.
    pub verify: bool,
}

impl Default for TransformSpec {
    fn default() -> Self {
        Self {
            port: PortKind::Lci,
            chunk: ChunkPolicy::default(),
            exec: ExecutionMode::Blocking,
            domain: Domain::Complex,
            threads_per_locality: 2,
            net: None,
            engine: ComputeEngine::Native,
            verify: true,
        }
    }
}

impl TransformSpec {
    /// Override from a parsed key=value [`Config`], reading the dotted
    /// keys `{prefix}.port`, `.chunk_bytes`, `.inflight`, `.exec`,
    /// `.domain`, `.threads`, `.engine` (`native` or
    /// `pjrt:<artifact-dir>`), and `.verify`. Keys that are absent leave
    /// the current value untouched; malformed values are rejected with
    /// the key name in the error.
    pub fn apply_kv(&mut self, cfg: &Config, prefix: &str) -> Result<()> {
        let key = |name: &str| format!("{prefix}.{name}");
        if let Some(v) = cfg.get(&key("port")) {
            self.port = v.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = cfg.get_parsed(&key("chunk_bytes"))? {
            anyhow::ensure!(v > 0, "{} must be positive", key("chunk_bytes"));
            self.chunk.chunk_bytes = v;
        }
        if let Some(v) = cfg.get_parsed(&key("inflight"))? {
            anyhow::ensure!(v > 0, "{} must be positive", key("inflight"));
            self.chunk.inflight = v;
        }
        if let Some(v) = cfg.get(&key("exec")) {
            self.exec = v.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = cfg.get(&key("domain")) {
            self.domain = v.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = cfg.get_parsed(&key("threads"))? {
            anyhow::ensure!(v > 0, "{} must be positive", key("threads"));
            self.threads_per_locality = v;
        }
        if let Some(v) = cfg.get(&key("engine")) {
            self.engine = v.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = cfg.get_parsed(&key("verify"))? {
            self.verify = v;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_driver_default() {
        let spec = TransformSpec::default();
        let drv = crate::dist_fft::DistFftConfig::default();
        assert_eq!(spec.port, drv.port);
        assert_eq!(spec.chunk, drv.chunk);
        assert_eq!(spec.exec, drv.exec);
        assert_eq!(spec.domain, drv.domain);
        assert_eq!(spec.threads_per_locality, drv.threads_per_locality);
        assert_eq!(spec.engine, drv.engine);
        assert_eq!(spec.verify, drv.verify);
    }

    #[test]
    fn kv_overrides() {
        let cfg = Config::parse(
            "[transform]\nport = mpi\nchunk_bytes = 4096\ninflight = 2\n\
             exec = async\ndomain = real\nthreads = 3\nverify = false\n",
        )
        .unwrap();
        let mut spec = TransformSpec::default();
        spec.apply_kv(&cfg, "transform").unwrap();
        assert_eq!(spec.port, PortKind::Mpi);
        assert_eq!(spec.chunk, ChunkPolicy::new(4096, 2));
        assert_eq!(spec.exec, ExecutionMode::Async);
        assert_eq!(spec.domain, Domain::Real);
        assert_eq!(spec.threads_per_locality, 3);
        assert!(!spec.verify);
    }

    #[test]
    fn kv_engine_parse() {
        let cfg = Config::parse("[t]\nengine = pjrt:artifacts/fft\n").unwrap();
        let mut spec = TransformSpec::default();
        spec.apply_kv(&cfg, "t").unwrap();
        assert_eq!(spec.engine, ComputeEngine::Pjrt("artifacts/fft".into()));
        let bad = Config::parse("[t]\nengine = cuda\n").unwrap();
        assert!(spec.apply_kv(&bad, "t").is_err());
    }

    #[test]
    fn kv_rejects_zero_chunk() {
        let cfg = Config::parse("[t]\nchunk_bytes = 0\n").unwrap();
        let mut spec = TransformSpec::default();
        let err = spec.apply_kv(&cfg, "t").unwrap_err().to_string();
        assert!(err.contains("t.chunk_bytes"), "{err}");
    }

    #[test]
    fn kv_absent_keys_leave_defaults() {
        let cfg = Config::parse("[t]\nport = tcp\n").unwrap();
        let mut spec = TransformSpec::default();
        spec.apply_kv(&cfg, "t").unwrap();
        assert_eq!(spec.port, PortKind::Tcp);
        assert_eq!(spec.exec, ExecutionMode::Blocking);
        assert!(spec.verify);
    }

    #[test]
    fn roundtrips_through_shape_configs() {
        let spec = TransformSpec {
            port: PortKind::Tcp,
            exec: ExecutionMode::Async,
            domain: Domain::Real,
            threads_per_locality: 1,
            verify: false,
            ..Default::default()
        };
        let mut drv = crate::dist_fft::DistFftConfig::default();
        drv.apply_spec(&spec);
        assert_eq!(drv.port, PortKind::Tcp);
        assert_eq!(drv.spec().exec, ExecutionMode::Async);
        let mut p3 = crate::dist_fft::Pencil3Config::default();
        p3.apply_spec(&spec);
        assert_eq!(p3.domain, Domain::Real);
        assert!(!p3.spec().verify);
    }
}
