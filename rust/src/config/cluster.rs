//! Cluster hardware specification — the paper's Fig. 2, as data.

use crate::parcelport::NetModel;
use crate::simnet::ComputeModel;

/// Hardware description of a benchmark cluster.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Cluster name.
    pub name: &'static str,
    /// Node count.
    pub nodes: usize,
    /// Interconnect description.
    pub connection: &'static str,
    /// Link speed, Gbit/s.
    pub link_gbits: f64,
    /// CPU sockets per node.
    pub sockets: usize,
    /// CPU model.
    pub cpu: &'static str,
    /// Cores per socket.
    pub cores_per_socket: usize,
    /// Base clock, GHz.
    pub clock_ghz: f64,
    /// L3 cache per node, MiB.
    pub l3_mb: usize,
    /// RAM per node, GiB.
    pub ram_gb: usize,
}

impl ClusterSpec {
    /// Fig. 2: the "buran" cluster.
    pub fn buran() -> Self {
        Self {
            name: "buran",
            nodes: 16,
            connection: "InfiniBand HDR",
            link_gbits: 200.0,
            sockets: 2,
            cpu: "AMD EPYC 7352",
            cores_per_socket: 24,
            clock_ghz: 2.3,
            l3_mb: 128,
            ram_gb: 256,
        }
    }

    /// The wire model implied by this spec.
    pub fn net_model(&self) -> NetModel {
        NetModel { beta_gbps: self.link_gbits / 8.0, ..NetModel::infiniband_hdr() }
    }

    /// The compute model implied by this spec (one socket's cores drive
    /// the FFT sweeps, as in the paper's MPI+pthreads setup).
    pub fn compute_model(&self) -> ComputeModel {
        ComputeModel { cores: self.cores_per_socket, ..ComputeModel::buran() }
    }

    /// Render the Fig. 2 table.
    pub fn render(&self) -> String {
        let mut t = crate::metrics::table::Table::new(&["Cluster", self.name]);
        t.row(&["Nodes".into(), self.nodes.to_string()]);
        t.row(&["Connection".into(), self.connection.into()]);
        t.row(&["Speed".into(), format!("{} Gb/s", self.link_gbits)]);
        t.row(&["Sockets".into(), self.sockets.to_string()]);
        t.row(&["CPU".into(), self.cpu.into()]);
        t.row(&["Cores".into(), self.cores_per_socket.to_string()]);
        t.row(&["Clock rate".into(), format!("{} GHz", self.clock_ghz)]);
        t.row(&["L3 Cache".into(), format!("{} MB", self.l3_mb)]);
        t.row(&["RAM".into(), format!("{} GB", self.ram_gb)]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buran_matches_fig2() {
        let b = ClusterSpec::buran();
        assert_eq!(b.nodes, 16);
        assert_eq!(b.link_gbits, 200.0);
        assert_eq!(b.cores_per_socket, 24);
        assert_eq!(b.ram_gb, 256);
    }

    #[test]
    fn net_model_is_25_gbytes() {
        assert_eq!(ClusterSpec::buran().net_model().beta_gbps, 25.0);
    }

    #[test]
    fn render_contains_all_rows() {
        let s = ClusterSpec::buran().render();
        for needle in ["buran", "InfiniBand", "200 Gb/s", "EPYC", "2.3 GHz", "128 MB", "256 GB"] {
            assert!(s.contains(needle), "missing {needle} in:\n{s}");
        }
    }
}
