//! Benchmark run matrices — the knobs of Figs. 3–5 as one struct, with
//! defaults scaled for a laptop-class live run and a `--paper-scale`
//! switch for the simnet prediction at the true problem size.

use super::kv::Config;
use crate::collectives::ChunkPolicy;
use crate::dist_fft::driver::ExecutionMode;
use crate::dist_fft::grid3::{Grid3, ProcGrid};
use anyhow::Result;

/// Parameters shared by the figure harnesses.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Repetitions per measured point (paper: 50).
    pub reps: usize,
    /// Warmup repetitions excluded from stats.
    pub warmup: usize,
    /// Live-mode grid (rows = cols).
    pub live_grid: usize,
    /// Live-mode locality counts to sweep.
    pub live_nodes: Vec<usize>,
    /// Simnet locality counts to sweep (paper: 1..16).
    pub sim_nodes: Vec<usize>,
    /// Simnet grid (paper: 2^14).
    pub sim_grid: usize,
    /// Chunk sizes for the Fig. 3 sweep, bytes.
    pub chunk_sizes: Vec<u64>,
    /// Wire-chunking policy used by the pipelined collectives
    /// (`PairwiseChunked` all-to-all, `Pipelined` scatter).
    pub pipeline: ChunkPolicy,
    /// Execution mode of the measured runs: blocking collectives or the
    /// future-chained async task graph (the `--exec` benchmark axis).
    pub exec: ExecutionMode,
    /// Threads per locality in live runs.
    pub threads: usize,
    /// Output directory for CSV series.
    pub out_dir: String,
    /// Global 3-D grid of the fig6 pencil sweep (`--grid3`).
    pub grid3: Grid3,
    /// `Pr × Pc` process-grid shapes the fig6 sweep covers
    /// (`--shapes`). Shapes that do not divide `grid3` are skipped with
    /// a notice.
    pub proc_shapes: Vec<ProcGrid>,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            reps: 50,
            warmup: 3,
            live_grid: 1 << 10,
            live_nodes: vec![1, 2, 4, 8],
            sim_nodes: vec![1, 2, 4, 8, 16],
            sim_grid: 1 << 14,
            // 1 KiB … 16 MiB, ×4 steps (the paper's log sweep), plus a
            // non-power-of-two point (1 MB decimal) — wire chunking and
            // the eager/rendezvous cutovers must not depend on
            // power-of-two payload sizes.
            chunk_sizes: {
                let mut sizes: Vec<u64> = (0..8).map(|i| 1024u64 << (2 * i)).collect();
                sizes.push(1_000_000);
                sizes.sort_unstable();
                sizes
            },
            pipeline: ChunkPolicy::default(),
            exec: ExecutionMode::Blocking,
            threads: 2,
            out_dir: "bench_out".into(),
            grid3: Grid3::new(32, 32, 32),
            proc_shapes: vec![ProcGrid::new(1, 4), ProcGrid::new(2, 2), ProcGrid::new(4, 1)],
        }
    }
}

impl BenchConfig {
    /// Quick mode for CI / smoke runs. Keeps one non-power-of-two sweep
    /// point (1 kB) so the smoke path exercises ragged wire chunking,
    /// and the non-power-of-two fig6 acceptance grid (12×8×24).
    pub fn quick() -> Self {
        Self {
            reps: 5,
            warmup: 1,
            live_grid: 1 << 8,
            live_nodes: vec![1, 2, 4],
            chunk_sizes: {
                let mut sizes: Vec<u64> = (0..5).map(|i| 1024u64 << (2 * i)).collect();
                sizes.push(1000);
                sizes.sort_unstable();
                sizes
            },
            grid3: Grid3::new(12, 8, 24),
            ..Self::default()
        }
    }

    /// The transform execution settings embedded in this run matrix, as
    /// a [`super::TransformSpec`] — the chunk policy, execution mode,
    /// and thread count carry over; the spec's other knobs (port,
    /// domain, ...) take their defaults because the harnesses sweep
    /// them per point.
    pub fn transform_spec(&self) -> super::TransformSpec {
        super::TransformSpec {
            chunk: self.pipeline,
            exec: self.exec,
            threads_per_locality: self.threads,
            ..super::TransformSpec::default()
        }
    }

    /// Override from a key=value config file (`bench.reps`, `bench.grid`, ...).
    pub fn apply_file(&mut self, path: &str) -> Result<()> {
        let cfg = Config::load(path)?;
        if let Some(v) = cfg.get_parsed("bench.reps")? {
            self.reps = v;
        }
        if let Some(v) = cfg.get_parsed("bench.warmup")? {
            self.warmup = v;
        }
        if let Some(v) = cfg.get_parsed("bench.live_grid")? {
            self.live_grid = v;
        }
        if let Some(v) = cfg.get_parsed("bench.sim_grid")? {
            self.sim_grid = v;
        }
        if let Some(v) = cfg.get_parsed("bench.threads")? {
            self.threads = v;
        }
        if let Some(v) = cfg.get_parsed("bench.chunk_bytes")? {
            anyhow::ensure!(v > 0, "bench.chunk_bytes must be positive");
            self.pipeline.chunk_bytes = v;
        }
        if let Some(v) = cfg.get_parsed("bench.inflight")? {
            anyhow::ensure!(v > 0, "bench.inflight must be positive");
            self.pipeline.inflight = v;
        }
        if let Some(v) = cfg.get("bench.exec") {
            self.exec = v.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = cfg.get("bench.grid3") {
            self.grid3 = v.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = cfg.get("bench.out_dir") {
            self.out_dir = v.to_string();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_methodology() {
        let c = BenchConfig::default();
        assert_eq!(c.reps, 50);
        assert_eq!(c.sim_grid, 1 << 14);
        assert_eq!(*c.sim_nodes.last().unwrap(), 16);
        assert_eq!(c.chunk_sizes[0], 1024);
        assert_eq!(*c.chunk_sizes.last().unwrap(), 16 << 20);
        // The sweep carries a non-power-of-two point.
        assert!(c.chunk_sizes.contains(&1_000_000));
        assert!(c.chunk_sizes.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
    }

    #[test]
    fn quick_is_smaller() {
        let q = BenchConfig::quick();
        assert!(q.reps < BenchConfig::default().reps);
        // The quick fig6 grid is the non-power-of-two acceptance shape.
        assert_eq!(q.grid3, Grid3::new(12, 8, 24));
    }

    #[test]
    fn fig6_defaults_cover_all_four_locality_shapes() {
        let c = BenchConfig::default();
        assert_eq!(
            c.proc_shapes,
            vec![ProcGrid::new(1, 4), ProcGrid::new(2, 2), ProcGrid::new(4, 1)]
        );
        assert!(c.proc_shapes.iter().all(|p| p.n() == 4));
    }

    #[test]
    fn grid3_from_file() {
        let dir = std::env::temp_dir().join(format!("hpxfft-bench3d-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.conf");
        std::fs::write(&path, "[bench]\ngrid3 = 24x16x8\n").unwrap();
        let mut c = BenchConfig::default();
        c.apply_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.grid3, Grid3::new(24, 16, 8));
        std::fs::write(&path, "[bench]\ngrid3 = 24x16\n").unwrap();
        assert!(c.apply_file(path.to_str().unwrap()).is_err());
    }

    #[test]
    fn file_overrides() {
        let dir = std::env::temp_dir().join(format!("hpxfft-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.conf");
        std::fs::write(&path, "[bench]\nreps = 7\nthreads = 3\nchunk_bytes = 4096\ninflight = 2\n")
            .unwrap();
        let mut c = BenchConfig::default();
        c.apply_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.reps, 7);
        assert_eq!(c.threads, 3);
        assert_eq!(c.pipeline, ChunkPolicy::new(4096, 2));
        assert_eq!(c.live_grid, 1 << 10); // untouched
        assert_eq!(c.exec, ExecutionMode::Blocking); // untouched default
    }

    #[test]
    fn exec_mode_from_file() {
        let dir = std::env::temp_dir().join(format!("hpxfft-benchexec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.conf");
        std::fs::write(&path, "[bench]\nexec = async\n").unwrap();
        let mut c = BenchConfig::default();
        c.apply_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.exec, ExecutionMode::Async);
        std::fs::write(&path, "[bench]\nexec = bogus\n").unwrap();
        assert!(c.apply_file(path.to_str().unwrap()).is_err());
    }

    #[test]
    fn zero_chunk_policy_in_file_rejected() {
        let dir = std::env::temp_dir().join(format!("hpxfft-bench0-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.conf");
        std::fs::write(&path, "[bench]\nchunk_bytes = 0\n").unwrap();
        let mut c = BenchConfig::default();
        let err = c.apply_file(path.to_str().unwrap()).unwrap_err().to_string();
        assert!(err.contains("chunk_bytes"), "{err}");
        assert_eq!(c.pipeline, ChunkPolicy::default(), "policy must be untouched on error");
    }
}
