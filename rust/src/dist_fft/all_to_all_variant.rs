//! Variant A — one synchronized *all-to-all* collective (paper Fig. 4).
//!
//! The transpose step cannot begin until the collective has delivered
//! every chunk: communication and computation are strictly serialized.
//! This is the baseline the N-scatter variant improves on.
//!
//! Exception: with [`AllToAllAlgo::PairwiseChunked`] the exchange streams
//! policy-sized wire chunks, and this variant fuses steps 2+3 — wire
//! chunk *k* is transpose-unpacked the moment it is matched, while chunk
//! *k+1* (and later rounds' sends) are still in flight. `transpose_us`
//! then reports the overlapped unpack time *inside* `comm_us`, the same
//! accounting the scatter variant uses.

use super::driver::{RowFft, StepTimings};
use super::partition::{FftInput, Slab};
use super::scatter_variant::hidden_us;
use super::transpose::{place_chunk_slice_transposed, place_chunk_transposed};
use crate::collectives::{AllToAllAlgo, Communicator};
use crate::fft::complex::{from_le_bytes, Complex32};
use crate::hpx::parcel::Payload;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Run the four-step distributed FFT with an all-to-all exchange
/// (complex domain — see [`run_input`]). Returns the locality's slab of
/// the transposed-layout result (`C/N × R`, row-major) and per-step
/// timings.
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `Variant::AllToAll` instead of \
            calling the variant entry point directly"
)]
pub fn run(
    comm: &Communicator,
    slab: &Slab,
    algo: AllToAllAlgo,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    run_input_impl(comm, &FftInput::Complex(slab), algo, nthreads, engine)
}

/// [`run`] over either input domain.
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `Variant::AllToAll` instead of \
            calling the variant entry point directly"
)]
pub fn run_input(
    comm: &Communicator,
    input: &FftInput<'_>,
    algo: AllToAllAlgo,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    run_input_impl(comm, input, algo, nthreads, engine)
}

/// Blocking all-to-all run over either input domain: stage 1 is
/// [`FftInput::stage1_band`] (c2c rows, or r2c into packed
/// half-spectra), and the exchange runs on the spectral geometry —
/// `C/2` columns in the real domain, halving the collective's payload.
pub(crate) fn run_input_impl(
    comm: &Communicator,
    input: &FftInput<'_>,
    algo: AllToAllAlgo,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    let n = comm.size();
    debug_assert_eq!(input.parts(), n, "input decomposition must match the communicator");
    let lr = input.local_rows();
    let cw = Slab::cols_per_chunk(input.spectral_cols(), n);
    let r_total = input.global_rows();
    let mut timings = StepTimings::default();
    let t_start = Instant::now();

    // Step 1: first-axis row transforms.
    let t0 = Instant::now();
    let mut work = input.stage1_seed();
    {
        let _span = crate::obs::span("fft", "stage1", comm.my_global());
        input.stage1_band(&mut work, 0, lr, engine, nthreads);
    }
    timings.fft1_us = t0.elapsed().as_secs_f64() * 1e6;

    // Step 2: chunk + exchange, on the spectral slab geometry.
    let tmp = Slab {
        global_rows: r_total,
        global_cols: input.spectral_cols(),
        parts: n,
        rank: comm.rank(),
        data: work,
    };
    let mut next = vec![Complex32::ZERO; cw * r_total];
    if algo == AllToAllAlgo::PairwiseChunked {
        // Steps 2+3 fused: every arriving wire chunk is transpose-placed
        // immediately, overlapping with the chunks still on the wire.
        const ELEM: usize = std::mem::size_of::<Complex32>();
        comm.set_chunk_policy(comm.chunk_policy().aligned(ELEM));
        let t0 = Instant::now();
        let chunks: Vec<Payload> = (0..n)
            .map(|j| Payload::new(tmp.extract_chunk_bytes(j)))
            .collect();
        let mut transpose_spent = 0.0f64;
        comm.all_to_all_chunked_each(chunks, |src, byte_off, payload| {
            let tt = Instant::now();
            let _span = crate::obs::span_args(
                "place",
                "chunk",
                comm.my_global(),
                src as i64,
                (byte_off / ELEM) as i64,
                payload.len() as i64,
            );
            let elems = from_le_bytes(payload.as_bytes());
            place_chunk_slice_transposed(
                &elems,
                byte_off / ELEM,
                lr,
                cw,
                &mut next,
                r_total,
                src * lr,
            );
            transpose_spent += tt.elapsed().as_secs_f64() * 1e6;
        });
        timings.comm_us = t0.elapsed().as_secs_f64() * 1e6;
        timings.transpose_us = transpose_spent; // overlapped inside comm_us
    } else {
        let t0 = Instant::now();
        let chunks: Vec<Payload> = (0..n)
            .map(|j| Payload::new(tmp.extract_chunk_bytes(j)))
            .collect();
        let received = comm.all_to_all(chunks, algo);
        timings.comm_us = t0.elapsed().as_secs_f64() * 1e6;

        // Step 3: transpose every received chunk into the new slab.
        let t0 = Instant::now();
        for (j, payload) in received.into_iter().enumerate() {
            let span = crate::obs::span_args(
                "place",
                "chunk",
                comm.my_global(),
                j as i64,
                crate::obs::NO_ARG,
                payload.len() as i64,
            );
            let chunk = from_le_bytes(payload.as_bytes());
            debug_assert_eq!(chunk.len(), lr * cw);
            place_chunk_transposed(&chunk, lr, cw, &mut next, r_total, j * lr);
            drop(span);
        }
        timings.transpose_us = t0.elapsed().as_secs_f64() * 1e6;
    }

    // Step 4: row FFTs of the transposed slab (length R).
    let t0 = Instant::now();
    {
        let _span = crate::obs::span("fft", "stage2", comm.my_global());
        engine.fft_rows(&mut next, r_total, nthreads);
    }
    timings.fft2_us = t0.elapsed().as_secs_f64() * 1e6;

    timings.total_us = t_start.elapsed().as_secs_f64() * 1e6;
    (next, timings)
}

/// Run the all-to-all variant as a future-chained graph (`--exec async`):
/// the exchange is posted through
/// [`Communicator::all_to_all_async`] — the SPMD thread never blocks in
/// the collective itself — and the transpose plus the second-dimension
/// row FFT run as continuations of "all chunks received", overlapping
/// whatever tail of this rank's own sends is still draining through the
/// send pool. The hidden wall time lands in [`StepTimings::overlap_us`].
///
/// The all-to-all is still a synchronized exchange (no per-chunk
/// placement for the monolithic algorithms), so the overlap window here
/// is structurally narrower than the scatter variant's — which is the
/// paper's Fig. 4-vs-5 point, now measurable on the blocking-vs-async
/// axis too.
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `Variant::AllToAll` and \
            `ExecutionMode::Async` instead of calling the variant entry point directly"
)]
pub fn run_async(
    comm: &Communicator,
    slab: &Slab,
    algo: AllToAllAlgo,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    run_async_input_impl(comm, &FftInput::Complex(slab), algo, nthreads, engine)
}

/// [`run_async`] over either input domain.
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `Variant::AllToAll` and \
            `ExecutionMode::Async` instead of calling the variant entry point directly"
)]
pub fn run_async_input(
    comm: &Communicator,
    input: &FftInput<'_>,
    algo: AllToAllAlgo,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    run_async_input_impl(comm, input, algo, nthreads, engine)
}

/// Future-chained all-to-all run over either input domain (see
/// [`run_input_impl`] for the stage-1 / spectral-geometry split).
pub(crate) fn run_async_input_impl(
    comm: &Communicator,
    input: &FftInput<'_>,
    algo: AllToAllAlgo,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    let n = comm.size();
    debug_assert_eq!(input.parts(), n, "input decomposition must match the communicator");
    let lr = input.local_rows();
    let cw = Slab::cols_per_chunk(input.spectral_cols(), n);
    let r_total = input.global_rows();
    let mut timings = StepTimings::default();
    let t_start = Instant::now();

    // Step 1: first-axis row transforms.
    let t0 = Instant::now();
    let mut work = input.stage1_seed();
    {
        let _span = crate::obs::span("fft", "stage1", comm.my_global());
        input.stage1_band(&mut work, 0, lr, engine, nthreads);
    }
    timings.fft1_us = t0.elapsed().as_secs_f64() * 1e6;

    // Step 2, posted not blocked: the collective returns immediately;
    // its result future completes when this rank's receives are in.
    const ELEM: usize = std::mem::size_of::<Complex32>();
    comm.set_chunk_policy(comm.chunk_policy().aligned(ELEM));
    let tmp = Slab {
        global_rows: r_total,
        global_cols: input.spectral_cols(),
        parts: n,
        rank: comm.rank(),
        data: work,
    };
    let t_post = Instant::now();
    let chunks: Vec<Payload> =
        (0..n).map(|j| Payload::new(tmp.extract_chunk_bytes(j))).collect();
    let (result, sends) = comm.all_to_all_async(chunks, algo).into_parts();
    let last_send_done: Arc<Mutex<Option<Instant>>> = Arc::new(Mutex::new(None));
    let stamp = Arc::clone(&last_send_done);
    // `when_each` fires in completion order, so the final write leaves
    // the last chunk's completion instant.
    let _sends_stamped = crate::task::when_each(sends.clone(), move |_, _| {
        *stamp.lock().unwrap() = Some(Instant::now());
    });
    let received = result.get();
    let t_recv_done = Instant::now();

    // Step 3 as a continuation: transpose while the send tail drains.
    // On a traced run these "place" spans sit alongside the still-open
    // "wire" spans of this rank's own sends — the overlap window.
    let mut next = vec![Complex32::ZERO; cw * r_total];
    let t_tr = Instant::now();
    for (j, payload) in received.into_iter().enumerate() {
        let span = crate::obs::span_args(
            "place",
            "chunk",
            comm.my_global(),
            j as i64,
            crate::obs::NO_ARG,
            payload.len() as i64,
        );
        let chunk = from_le_bytes(payload.as_bytes());
        debug_assert_eq!(chunk.len(), lr * cw);
        place_chunk_transposed(&chunk, lr, cw, &mut next, r_total, j * lr);
        drop(span);
    }
    let t_tr_end = Instant::now();
    timings.transpose_us = t_tr_end.duration_since(t_tr).as_secs_f64() * 1e6;

    // Step 4 as the next continuation, still ahead of the send drain.
    let t_f2 = Instant::now();
    {
        let _span = crate::obs::span("fft", "stage2", comm.my_global());
        engine.fft_rows(&mut next, r_total, nthreads);
    }
    let t_f2_end = Instant::now();
    timings.fft2_us = t_f2_end.duration_since(t_f2).as_secs_f64() * 1e6;

    // Settle the outgoing chunks last.
    for s in sends {
        s.get();
    }
    let sends_done = last_send_done.lock().unwrap().take().unwrap_or(t_recv_done);
    let comm_close = t_recv_done.max(sends_done);
    timings.comm_us = comm_close.duration_since(t_post).as_secs_f64() * 1e6;
    timings.overlap_us =
        hidden_us(t_tr, t_tr_end, sends_done) + hidden_us(t_f2, t_f2_end, sends_done);
    timings.total_us = t_start.elapsed().as_secs_f64() * 1e6;
    (next, timings)
}

#[cfg(test)]
// Exercises the deprecated variant shims on purpose — shim coverage
// until every external caller has migrated to `TransformRequest`.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::dist_fft::driver::NativeRowFft;
    use crate::dist_fft::verify::{rel_error, serial_fft2_transposed};
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    fn check_variant(rows: usize, cols: usize, parts: usize, kind: PortKind, algo: AllToAllAlgo) {
        let cluster = Cluster::new(parts, kind, None).unwrap();
        let pieces = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
            let (out, _t) = run(&comm, &slab, algo, 1, &NativeRowFft);
            out
        });
        // Reassemble: rank i holds rows [i·cw, (i+1)·cw) of the C×R result.
        let mut assembled = Vec::with_capacity(rows * cols);
        for p in pieces {
            assembled.extend(p);
        }
        let reference = serial_fft2_transposed(&Slab::whole(rows, cols).data, rows, cols);
        let err = rel_error(&assembled, &reference);
        assert!(err < 1e-4, "rel err {err} ({kind} {algo:?} {parts} parts)");
    }

    #[test]
    fn matches_serial_lci() {
        check_variant(16, 32, 4, PortKind::Lci, AllToAllAlgo::Linear);
    }

    #[test]
    fn matches_serial_mpi_pairwise() {
        check_variant(32, 16, 4, PortKind::Mpi, AllToAllAlgo::Pairwise);
    }

    #[test]
    fn matches_serial_tcp_bruck() {
        check_variant(16, 16, 2, PortKind::Tcp, AllToAllAlgo::Bruck);
    }

    #[test]
    fn matches_serial_hpx_root() {
        check_variant(16, 16, 4, PortKind::Lci, AllToAllAlgo::HpxRoot);
    }

    #[test]
    fn matches_serial_pairwise_chunked_default_policy() {
        // Default 1 MiB chunks: single-chunk fast path.
        check_variant(16, 32, 4, PortKind::Lci, AllToAllAlgo::PairwiseChunked);
        check_variant(16, 16, 2, PortKind::Tcp, AllToAllAlgo::PairwiseChunked);
    }

    #[test]
    fn matches_serial_pairwise_chunked_tiny_chunks() {
        // Small wire chunks force the streaming overlap path: each
        // message (4×8 complex = 256 B) splits into four 64 B chunks that
        // are transpose-placed on arrival.
        use crate::collectives::ChunkPolicy;
        for kind in PortKind::ALL {
            let (rows, cols, parts) = (16, 32, 4);
            let cluster = Cluster::new(parts, kind, None).unwrap();
            let pieces = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.set_chunk_policy(ChunkPolicy::new(64, 2));
                let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
                run(&comm, &slab, AllToAllAlgo::PairwiseChunked, 1, &NativeRowFft).0
            });
            let mut assembled = Vec::with_capacity(rows * cols);
            for p in pieces {
                assembled.extend(p);
            }
            let reference = serial_fft2_transposed(&Slab::whole(rows, cols).data, rows, cols);
            let err = rel_error(&assembled, &reference);
            assert!(err < 1e-4, "rel err {err} ({kind})");
        }
    }

    #[test]
    fn chunked_timings_report_overlapped_transpose() {
        use crate::collectives::ChunkPolicy;
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        let timings = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            comm.set_chunk_policy(ChunkPolicy::new(128, 2));
            let slab = Slab::synthetic(16, 16, 2, ctx.rank);
            run(&comm, &slab, AllToAllAlgo::PairwiseChunked, 1, &NativeRowFft).1
        });
        for t in timings {
            // Fused accounting: the unpack happens inside the comm phase.
            assert!(t.transpose_us > 0.0);
            assert!(t.comm_us >= t.transpose_us, "{t:?}");
        }
    }

    #[test]
    fn single_locality_degenerate() {
        check_variant(8, 8, 1, PortKind::Lci, AllToAllAlgo::Linear);
    }

    #[test]
    fn rectangular_grids() {
        check_variant(8, 64, 2, PortKind::Lci, AllToAllAlgo::Pairwise);
        check_variant(64, 8, 2, PortKind::Lci, AllToAllAlgo::Pairwise);
    }

    #[test]
    fn matches_serial_non_pow2_all_ports() {
        // 12×96 over 4 localities, chunked and monolithic exchanges.
        for kind in PortKind::ALL {
            check_variant(12, 96, 4, kind, AllToAllAlgo::Pairwise);
            check_variant(12, 96, 4, kind, AllToAllAlgo::PairwiseChunked);
        }
    }

    #[test]
    fn async_matches_blocking_bitwise() {
        use crate::collectives::ChunkPolicy;
        let (rows, cols, parts) = (12, 24, 4);
        for kind in PortKind::ALL {
            for algo in [AllToAllAlgo::Linear, AllToAllAlgo::PairwiseChunked] {
                let run_mode = |async_mode: bool| {
                    let cluster = Cluster::new(parts, kind, None).unwrap();
                    cluster.run(|ctx| {
                        let comm = Communicator::from_ctx(ctx);
                        comm.set_chunk_policy(ChunkPolicy::new(96, 2));
                        let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
                        if async_mode {
                            run_async(&comm, &slab, algo, 1, &NativeRowFft).0
                        } else {
                            run(&comm, &slab, algo, 1, &NativeRowFft).0
                        }
                    })
                };
                assert_eq!(run_mode(false), run_mode(true), "{kind} {algo:?}");
            }
        }
    }

    #[test]
    fn async_matches_serial_every_algo() {
        let (rows, cols, parts) = (16, 16, 4);
        for algo in AllToAllAlgo::ALL {
            let cluster = Cluster::new(parts, PortKind::Lci, None).unwrap();
            let pieces = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
                run_async(&comm, &slab, algo, 1, &NativeRowFft).0
            });
            let mut assembled = Vec::with_capacity(rows * cols);
            for p in pieces {
                assembled.extend(p);
            }
            let reference = serial_fft2_transposed(&Slab::whole(rows, cols).data, rows, cols);
            let err = rel_error(&assembled, &reference);
            assert!(err < 1e-4, "rel err {err} ({algo:?})");
        }
    }

    #[test]
    fn timings_are_populated() {
        let cluster = Cluster::new(2, PortKind::Lci, None).unwrap();
        let t = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(8, 8, 2, ctx.rank);
            let (_out, t) = run(&comm, &slab, AllToAllAlgo::Linear, 1, &NativeRowFft);
            t
        });
        for t in t {
            assert!(t.total_us > 0.0);
            assert!(t.fft1_us > 0.0 && t.fft2_us > 0.0);
        }
    }
}
