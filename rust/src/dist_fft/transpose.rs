//! Chunk transposes — step 3 of the four-step algorithm.
//!
//! After communication, locality `i` holds one `lr × cw` chunk from every
//! locality `j` (`lr` = sender's local rows, `cw = C/N` columns). The new
//! local slab is `cw × R`: the chunk from `j`, transposed, lands in
//! columns `[j·lr, (j+1)·lr)`.
//!
//! `place_chunk_transposed` is the hot loop the scatter variant overlaps
//! with communication; it is cache-blocked (`BLOCK × BLOCK` tiles) because
//! at the paper's sizes a naive column-strided write thrashes L1 — see
//! EXPERIMENTS.md §Perf for the measured effect.

use crate::fft::complex::Complex32;

/// Cache-block edge for the tiled transpose (64 × 64 complex = 64 KiB
/// working set: fits L2, two tiles fit L1d? 64×64×8 = 32 KiB per tile).
/// Public so diagnostics (`repro kernels`) and the roofline bench can
/// report the tile geometry alongside their numbers.
pub const BLOCK: usize = 64;

/// Tiled transpose-place of whole rows: `rows` holds contiguous
/// row-major rows `r0..r0 + rows.len()/src_cols` of a chunk, and each
/// element lands at `slab[c][col0 + r0 + r]` — the shared inner loop of
/// [`place_chunk_transposed`] and [`place_chunk_slice_transposed`].
///
/// §Perf (EXPERIMENTS.md §Perf L3-2): within a `BLOCK × BLOCK` tile,
/// iterate the *destination* row (source column) in the outer loop so
/// writes are contiguous runs; the strided side is the read, which
/// prefetches better than strided writes commit.
// xtask: hot_path
fn place_rows_tiled(
    rows: &[Complex32],
    r0: usize,
    src_cols: usize,
    slab: &mut [Complex32],
    slab_cols: usize,
    col0: usize,
) {
    debug_assert_eq!(rows.len() % src_cols, 0, "whole rows only");
    let nrows = rows.len() / src_cols;
    let mut rb = 0;
    while rb < nrows {
        let r_hi = (rb + BLOCK).min(nrows);
        let mut cb = 0;
        while cb < src_cols {
            let c_hi = (cb + BLOCK).min(src_cols);
            for c in cb..c_hi {
                let dst_base = c * slab_cols + col0 + r0;
                for r in rb..r_hi {
                    slab[dst_base + r] = rows[r * src_cols + c];
                }
            }
            cb = c_hi;
        }
        rb = r_hi;
    }
}

/// Transpose `chunk` (`src_rows × src_cols`, row-major) into `slab`
/// (`src_cols × slab_cols`, row-major) at column offset `col0`:
///
/// `slab[c][col0 + r] = chunk[r][c]`.
// xtask: hot_path
pub fn place_chunk_transposed(
    chunk: &[Complex32],
    src_rows: usize,
    src_cols: usize,
    slab: &mut [Complex32],
    slab_cols: usize,
    col0: usize,
) {
    assert_eq!(chunk.len(), src_rows * src_cols, "chunk shape mismatch");
    assert!(col0 + src_rows <= slab_cols, "chunk overflows slab columns");
    assert!(
        slab.len() >= src_cols * slab_cols,
        "slab too small: {} < {}",
        slab.len(),
        src_cols * slab_cols
    );

    place_rows_tiled(chunk, 0, src_cols, slab, slab_cols, col0);
}

/// Transpose-place an arbitrary *window* of a `src_rows × src_cols`
/// chunk: `elems` holds the chunk's elements `[elem_offset, elem_offset +
/// elems.len())` in row-major order, and each lands at the position
/// `place_chunk_transposed` would have put it.
///
/// This is the unpack step of the chunk-pipelined exchange: wire chunk
/// *k* is placed while chunk *k+1* is still in flight, so the window is
/// whatever byte range the [`crate::collectives::ChunkPolicy`] cut — any
/// element-aligned offset, including mid-row.
// xtask: hot_path
pub fn place_chunk_slice_transposed(
    elems: &[Complex32],
    elem_offset: usize,
    src_rows: usize,
    src_cols: usize,
    slab: &mut [Complex32],
    slab_cols: usize,
    col0: usize,
) {
    assert!(
        elem_offset + elems.len() <= src_rows * src_cols,
        "window [{elem_offset}, +{}) exceeds chunk {src_rows}×{src_cols}",
        elems.len()
    );
    assert!(col0 + src_rows <= slab_cols, "chunk overflows slab columns");
    assert!(
        slab.len() >= src_cols * slab_cols,
        "slab too small: {} < {}",
        slab.len(),
        src_cols * slab_cols
    );

    if elems.is_empty() {
        return;
    }

    // Ragged head: a window cut mid-row starts with a partial leading
    // row, placed element by element (at most src_cols - 1 writes).
    let mut i = 0;
    let c0 = elem_offset % src_cols;
    if c0 != 0 {
        let r = elem_offset / src_cols;
        let run = (src_cols - c0).min(elems.len());
        for (k, v) in elems[..run].iter().enumerate() {
            slab[(c0 + k) * slab_cols + col0 + r] = *v;
        }
        i = run;
    }

    // Aligned middle: whole rows go through the same BLOCK × BLOCK tiled
    // loop as the one-shot path, instead of the strided single-element
    // walk the pre-tiling code used.
    let full_rows = (elems.len() - i) / src_cols;
    if full_rows > 0 {
        let r0 = (elem_offset + i) / src_cols;
        place_rows_tiled(&elems[i..i + full_rows * src_cols], r0, src_cols, slab, slab_cols, col0);
        i += full_rows * src_cols;
    }

    // Ragged tail: a partial trailing row (starts at column 0).
    if i < elems.len() {
        let r = (elem_offset + i) / src_cols;
        for (k, v) in elems[i..].iter().enumerate() {
            slab[k * slab_cols + col0 + r] = *v;
        }
    }
}

/// Full out-of-place transpose of a row-major `rows × cols` matrix
/// (serial reference path).
pub fn transpose(data: &[Complex32], rows: usize, cols: usize) -> Vec<Complex32> {
    assert_eq!(data.len(), rows * cols);
    let mut out = vec![Complex32::ZERO; rows * cols];
    place_chunk_transposed(data, rows, cols, &mut out, rows, 0);
    out
}

/// Untiled textbook transpose — the baseline the roofline bench measures
/// the `BLOCK × BLOCK` tiled path against, and the oracle the
/// equivalence tests compare it to. Kept deliberately naive (row-major
/// read, column-strided write).
pub fn transpose_naive(data: &[Complex32], rows: usize, cols: usize) -> Vec<Complex32> {
    assert_eq!(data.len(), rows * cols);
    let mut out = vec![Complex32::ZERO; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = data[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn grid(rows: usize, cols: usize, seed: u64) -> Vec<Complex32> {
        let mut rng = Pcg32::new(seed);
        (0..rows * cols).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
    }

    #[test]
    fn transpose_small_known() {
        // 2×3 → 3×2.
        let m: Vec<Complex32> = (0..6).map(|i| Complex32::new(i as f32, 0.0)).collect();
        let t = transpose(&m, 2, 3);
        let expect: Vec<f32> = vec![0.0, 3.0, 1.0, 4.0, 2.0, 5.0];
        assert_eq!(t.iter().map(|c| c.re).collect::<Vec<_>>(), expect);
    }

    #[test]
    fn transpose_is_involution() {
        let m = grid(33, 17, 4);
        let tt = transpose(&transpose(&m, 33, 17), 17, 33);
        assert_eq!(m, tt);
    }

    #[test]
    fn transpose_crosses_block_boundaries() {
        // > BLOCK in both dimensions exercises the tiling edges.
        let rows = BLOCK + 7;
        let cols = BLOCK * 2 + 3;
        let m = grid(rows, cols, 5);
        let t = transpose(&m, rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(t[c * rows + r], m[r * cols + c], "r={r} c={c}");
            }
        }
    }

    #[test]
    fn place_chunk_at_offset() {
        // Two 2×3 chunks placed side by side into a 3×4 slab.
        let chunk_a: Vec<Complex32> = (0..6).map(|i| Complex32::new(i as f32, 0.0)).collect();
        let chunk_b: Vec<Complex32> =
            (0..6).map(|i| Complex32::new(10.0 + i as f32, 0.0)).collect();
        let mut slab = vec![Complex32::ZERO; 3 * 4];
        place_chunk_transposed(&chunk_a, 2, 3, &mut slab, 4, 0);
        place_chunk_transposed(&chunk_b, 2, 3, &mut slab, 4, 2);
        // slab[c][0..2] = chunk_a[.][c]; slab[c][2..4] = chunk_b[.][c].
        #[rustfmt::skip]
        let expect: Vec<f32> = vec![
            0.0, 3.0, 10.0, 13.0,
            1.0, 4.0, 11.0, 14.0,
            2.0, 5.0, 12.0, 15.0,
        ];
        assert_eq!(slab.iter().map(|c| c.re).collect::<Vec<_>>(), expect);
    }

    #[test]
    fn slice_placement_matches_whole_chunk() {
        // Placing a chunk window by window — at awkward, mid-row split
        // points — must equal the one-shot whole-chunk placement.
        let (src_rows, src_cols) = (6, 10);
        let chunk = grid(src_rows, src_cols, 7);
        let slab_cols = 8;
        let mut whole = vec![Complex32::ZERO; src_cols * slab_cols];
        place_chunk_transposed(&chunk, src_rows, src_cols, &mut whole, slab_cols, 2);

        for window in [1usize, 3, 7, 10, 13, 60] {
            let mut piecewise = vec![Complex32::ZERO; src_cols * slab_cols];
            let mut off = 0;
            while off < chunk.len() {
                let hi = (off + window).min(chunk.len());
                place_chunk_slice_transposed(
                    &chunk[off..hi],
                    off,
                    src_rows,
                    src_cols,
                    &mut piecewise,
                    slab_cols,
                    2,
                );
                off = hi;
            }
            assert_eq!(piecewise, whole, "window {window}");
        }
    }

    #[test]
    fn empty_slice_placement_is_noop() {
        let mut slab = vec![Complex32::ONE; 4];
        place_chunk_slice_transposed(&[], 4, 2, 2, &mut slab, 2, 0);
        assert_eq!(slab, vec![Complex32::ONE; 4]);
    }

    #[test]
    #[should_panic(expected = "exceeds chunk")]
    fn slice_window_overflow_detected() {
        let mut slab = vec![Complex32::ZERO; 4];
        place_chunk_slice_transposed(&[Complex32::ZERO; 3], 2, 2, 2, &mut slab, 2, 0);
    }

    #[test]
    #[should_panic(expected = "overflows slab")]
    fn overflow_detected() {
        let chunk = vec![Complex32::ZERO; 4];
        let mut slab = vec![Complex32::ZERO; 4];
        place_chunk_transposed(&chunk, 2, 2, &mut slab, 2, 1);
    }

    #[test]
    fn tiled_matches_naive_awkward_shapes() {
        // Non-square, non-tile-multiple, and degenerate shapes: the tiled
        // path must agree with the untiled oracle bitwise.
        for &(rows, cols) in &[
            (33usize, 17usize),
            (257, 130),
            (70, 1),
            (1, 70),
            (BLOCK, BLOCK),
            (BLOCK + 7, 2 * BLOCK + 3),
        ] {
            let m = grid(rows, cols, (rows * 1000 + cols) as u64);
            assert_eq!(
                transpose(&m, rows, cols),
                transpose_naive(&m, rows, cols),
                "{rows}x{cols}"
            );
        }
    }

    #[test]
    fn slice_placement_matches_whole_chunk_across_tiles() {
        // Same window-by-window equivalence as above, but on a chunk
        // bigger than a tile in both dimensions and with windows that
        // land mid-row, exactly one row, and several-rows-plus-a-ragged-
        // edge — the head/tiled-middle/tail seams of the slice path.
        let (src_rows, src_cols) = (BLOCK + 5, BLOCK + 3);
        let chunk = grid(src_rows, src_cols, 21);
        let slab_cols = src_rows + 4;
        let mut whole = vec![Complex32::ZERO; src_cols * slab_cols];
        place_chunk_transposed(&chunk, src_rows, src_cols, &mut whole, slab_cols, 3);

        for window in [1usize, src_cols - 1, src_cols, src_cols + 1, 5 * src_cols + 17, 4096] {
            let mut piecewise = vec![Complex32::ZERO; src_cols * slab_cols];
            let mut off = 0;
            while off < chunk.len() {
                let hi = (off + window).min(chunk.len());
                place_chunk_slice_transposed(
                    &chunk[off..hi],
                    off,
                    src_rows,
                    src_cols,
                    &mut piecewise,
                    slab_cols,
                    3,
                );
                off = hi;
            }
            assert_eq!(piecewise, whole, "window {window}");
        }
    }

    #[test]
    fn square_block_multiple() {
        let m = grid(BLOCK * 2, BLOCK * 2, 6);
        let t = transpose(&m, BLOCK * 2, BLOCK * 2);
        for r in 0..BLOCK * 2 {
            for c in 0..BLOCK * 2 {
                assert_eq!(t[c * BLOCK * 2 + r], m[r * BLOCK * 2 + c]);
            }
        }
    }
}
