//! Slab decomposition of the global 2-D grid — complex ([`Slab`]) and
//! real ([`RealSlab`]) input domains, unified behind [`FftInput`] for
//! the distributed drivers.

use super::driver::RowFft;
use crate::fft::complex::Complex32;
use crate::fft::real::rfft_rows_packed_into;
use crate::util::rng::Pcg32;

/// One locality's row-slab of the global `R × C` grid.
#[derive(Clone, Debug)]
pub struct Slab {
    /// Global grid rows.
    pub global_rows: usize,
    /// Global grid cols.
    pub global_cols: usize,
    /// Number of participating localities.
    pub parts: usize,
    /// Which slab this is.
    pub rank: usize,
    /// Row-major local data, `local_rows() × global_cols`.
    pub data: Vec<Complex32>,
}

impl Slab {
    /// Rows held by each locality. `R` must divide evenly by the
    /// locality count; beyond that any length is fine — the mixed-radix
    /// planner handles non-power-of-two rows (the paper's own grids are
    /// `2^k` on power-of-two node counts, the conservative special case).
    pub fn rows_per_part(global_rows: usize, parts: usize) -> usize {
        assert!(parts > 0, "need at least one part");
        assert!(
            global_rows % parts == 0,
            "global rows {global_rows} not divisible by {parts} localities"
        );
        global_rows / parts
    }

    /// Columns per all-to-all chunk (`C` must divide evenly).
    pub fn cols_per_chunk(global_cols: usize, parts: usize) -> usize {
        assert!(
            global_cols % parts == 0,
            "global cols {global_cols} not divisible by {parts} localities"
        );
        global_cols / parts
    }

    /// Rows in this slab.
    pub fn local_rows(&self) -> usize {
        Self::rows_per_part(self.global_rows, self.parts)
    }

    /// First global row of this slab.
    pub fn row_offset(&self) -> usize {
        self.rank * self.local_rows()
    }

    /// Allocate a zeroed slab.
    pub fn zeroed(global_rows: usize, global_cols: usize, parts: usize, rank: usize) -> Self {
        assert!(rank < parts, "rank {rank} out of range");
        let local_rows = Self::rows_per_part(global_rows, parts);
        Self {
            global_rows,
            global_cols,
            parts,
            rank,
            data: vec![Complex32::ZERO; local_rows * global_cols],
        }
    }

    /// Deterministic synthetic signal: every locality can generate its
    /// slab independently, and a serial process can generate the whole
    /// grid bit-identically (verification depends on this).
    pub fn synthetic(global_rows: usize, global_cols: usize, parts: usize, rank: usize) -> Self {
        let mut slab = Self::zeroed(global_rows, global_cols, parts, rank);
        let local_rows = slab.local_rows();
        let row0 = slab.row_offset();
        for r in 0..local_rows {
            let grow = row0 + r;
            // One RNG stream per global row → decomposition-independent.
            let mut rng = Pcg32::with_stream(0x0B5E_2411, grow as u64 + 1);
            for c in 0..global_cols {
                slab.data[r * global_cols + c] = Complex32::new(rng.next_signal(), rng.next_signal());
            }
        }
        slab
    }

    /// The whole global grid as one slab (serial reference).
    pub fn whole(global_rows: usize, global_cols: usize) -> Self {
        Self::synthetic(global_rows, global_cols, 1, 0)
    }

    /// Extract the column-block chunk destined for locality `j` as a
    /// contiguous row-major `local_rows × cols_per_chunk` buffer.
    pub fn extract_chunk(&self, j: usize) -> Vec<Complex32> {
        let lr = self.local_rows();
        let cw = Self::cols_per_chunk(self.global_cols, self.parts);
        let c0 = j * cw;
        let mut out = Vec::with_capacity(lr * cw);
        for r in 0..lr {
            let base = r * self.global_cols + c0;
            out.extend_from_slice(&self.data[base..base + cw]);
        }
        out
    }

    /// Extract the chunk for locality `j` directly as a wire-format byte
    /// buffer — one pass, one allocation (§Perf: replaces
    /// `extract_chunk` + re-serialization on the send path).
    pub fn extract_chunk_bytes(&self, j: usize) -> Vec<u8> {
        let lr = self.local_rows();
        let cw = Self::cols_per_chunk(self.global_cols, self.parts);
        let c0 = j * cw;
        let mut out = Vec::with_capacity(lr * cw * std::mem::size_of::<Complex32>());
        for r in 0..lr {
            let base = r * self.global_cols + c0;
            out.extend_from_slice(crate::fft::complex::as_byte_slice(
                &self.data[base..base + cw],
            ));
        }
        out
    }

    /// Extract rows `[r0, r1)` of the column-block chunk destined for
    /// locality `j` from a working buffer (`local_rows × global_cols`,
    /// row-major) as wire-format bytes — the banded variant of
    /// [`Slab::extract_chunk_bytes`]. The async FFT driver uses this to
    /// post a wire chunk the moment the rows feeding it finish their
    /// first-dimension FFT, while later rows are still being transformed.
    pub fn extract_chunk_rows_bytes(
        data: &[crate::fft::complex::Complex32],
        global_cols: usize,
        parts: usize,
        j: usize,
        r0: usize,
        r1: usize,
    ) -> Vec<u8> {
        let cw = Self::cols_per_chunk(global_cols, parts);
        let c0 = j * cw;
        assert!(r0 <= r1, "inverted row band [{r0}, {r1})");
        assert!(r1 * global_cols <= data.len(), "band exceeds buffer");
        let mut out =
            Vec::with_capacity((r1 - r0) * cw * std::mem::size_of::<Complex32>());
        for r in r0..r1 {
            let base = r * global_cols + c0;
            out.extend_from_slice(crate::fft::complex::as_byte_slice(
                &data[base..base + cw],
            ));
        }
        out
    }

    /// Bytes a locality sends during the communication step:
    /// `(1 − 1/N)` of its slab, 8 bytes per complex element.
    pub fn bytes_sent_per_locality(&self) -> usize {
        let total = self.local_rows() * self.global_cols * std::mem::size_of::<Complex32>();
        total - total / self.parts
    }
}

/// One locality's row-slab of a *real-valued* global `R × C` grid — the
/// input domain of the paper's FFTW3+MPI reference workload. Stage 1 of
/// the distributed pipeline transforms each real row into a packed
/// half-spectrum of `C/2` complex bins
/// ([`crate::fft::real::rfft_rows_packed_into`]), so every transpose
/// round moves half the bytes of the complex-domain run on the same
/// grid.
#[derive(Clone, Debug)]
pub struct RealSlab {
    /// Global grid rows.
    pub global_rows: usize,
    /// Global grid cols (the real first-axis length; must be even for
    /// the packed distributed path).
    pub global_cols: usize,
    /// Number of participating localities.
    pub parts: usize,
    /// Which slab this is.
    pub rank: usize,
    /// Row-major local real samples, `local_rows() × global_cols`.
    pub data: Vec<f32>,
}

impl RealSlab {
    /// Rows in this slab.
    pub fn local_rows(&self) -> usize {
        Slab::rows_per_part(self.global_rows, self.parts)
    }

    /// First global row of this slab.
    pub fn row_offset(&self) -> usize {
        self.rank * self.local_rows()
    }

    /// Columns of the packed half-spectrum each row transforms into.
    ///
    /// # Panics
    /// If `global_cols` is odd (the packed layout needs paired bins).
    pub fn packed_cols(&self) -> usize {
        assert!(
            self.global_cols % 2 == 0,
            "real slab cols {} must be even for the packed half-spectrum",
            self.global_cols
        );
        self.global_cols / 2
    }

    /// Deterministic synthetic real signal, decomposition-independent
    /// like [`Slab::synthetic`]: one RNG stream per global row (a
    /// distinct stream constant from the complex slab, so the two
    /// domains are independent datasets).
    pub fn synthetic(global_rows: usize, global_cols: usize, parts: usize, rank: usize) -> Self {
        assert!(rank < parts, "rank {rank} out of range");
        let local_rows = Slab::rows_per_part(global_rows, parts);
        let mut slab = Self {
            global_rows,
            global_cols,
            parts,
            rank,
            data: vec![0.0; local_rows * global_cols],
        };
        let row0 = slab.row_offset();
        for r in 0..local_rows {
            let grow = row0 + r;
            let mut rng = Pcg32::with_stream(0x0B5E_2412, grow as u64 + 1);
            for c in 0..global_cols {
                slab.data[r * global_cols + c] = rng.next_signal();
            }
        }
        slab
    }

    /// The whole real global grid as one slab (serial reference).
    pub fn whole(global_rows: usize, global_cols: usize) -> Self {
        Self::synthetic(global_rows, global_cols, 1, 0)
    }
}

/// Input-domain selector the distributed 2-D variants run over: the
/// paper's complex transform, or the real-input (r2c) transform whose
/// stage 1 emits packed half-spectra. Everything downstream of stage 1
/// — chunk extraction, the wire protocol, transpose placement, the
/// second-axis FFT — is domain-agnostic and just sees a spectral slab
/// of [`FftInput::spectral_cols`] complex columns.
pub enum FftInput<'a> {
    /// Complex-domain input (c2c — the paper's benchmark).
    Complex(&'a Slab),
    /// Real-domain input (r2c first axis, packed half-spectra on the
    /// wire — half the transpose payload).
    Real(&'a RealSlab),
}

impl FftInput<'_> {
    /// Global grid rows (the second-axis transform length).
    pub fn global_rows(&self) -> usize {
        match self {
            FftInput::Complex(s) => s.global_rows,
            FftInput::Real(s) => s.global_rows,
        }
    }

    /// Number of participating localities.
    pub fn parts(&self) -> usize {
        match self {
            FftInput::Complex(s) => s.parts,
            FftInput::Real(s) => s.parts,
        }
    }

    /// Which slab this is.
    pub fn rank(&self) -> usize {
        match self {
            FftInput::Complex(s) => s.rank,
            FftInput::Real(s) => s.rank,
        }
    }

    /// Rows in this locality's slab.
    pub fn local_rows(&self) -> usize {
        match self {
            FftInput::Complex(s) => s.local_rows(),
            FftInput::Real(s) => s.local_rows(),
        }
    }

    /// Columns of the *spectral* slab stage 1 produces: `C` for the
    /// complex domain, `C/2` packed bins for the real domain — the
    /// column count every transpose round actually moves.
    pub fn spectral_cols(&self) -> usize {
        match self {
            FftInput::Complex(s) => s.global_cols,
            FftInput::Real(s) => s.packed_cols(),
        }
    }

    /// Stage-1 working buffer (`local_rows × spectral_cols`):
    /// the complex domain transforms its slab in place, so the seed is a
    /// copy of the input; the real domain writes packed rows into a
    /// zeroed buffer.
    pub(crate) fn stage1_seed(&self) -> Vec<Complex32> {
        match self {
            FftInput::Complex(s) => s.data.clone(),
            FftInput::Real(s) => {
                vec![Complex32::ZERO; s.local_rows() * s.packed_cols()]
            }
        }
    }

    /// Transform rows `[r0, r1)` of the stage-1 buffer: the banded
    /// first-axis FFT. Rows are independent, so any band split produces
    /// bitwise-identical spectra — the async drivers lean on this to
    /// stream wire chunks out of partially transformed slabs.
    pub(crate) fn stage1_band(
        &self,
        work: &mut [Complex32],
        r0: usize,
        r1: usize,
        engine: &dyn RowFft,
        nthreads: usize,
    ) {
        match self {
            FftInput::Complex(s) => {
                let c = s.global_cols;
                engine.fft_rows(&mut work[r0 * c..r1 * c], c, nthreads);
            }
            FftInput::Real(s) => {
                let c = s.global_cols;
                let m = s.packed_cols();
                rfft_rows_packed_into(
                    &s.data[r0 * c..r1 * c],
                    c,
                    &mut work[r0 * m..r1 * m],
                    nthreads,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_divide() {
        assert_eq!(Slab::rows_per_part(16, 4), 4);
        assert_eq!(Slab::rows_per_part(16, 1), 16);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn ragged_rows_rejected() {
        Slab::rows_per_part(10, 4);
    }

    #[test]
    fn synthetic_is_decomposition_independent() {
        let whole = Slab::whole(8, 4);
        for parts in [2usize, 4] {
            for rank in 0..parts {
                let slab = Slab::synthetic(8, 4, parts, rank);
                let lr = slab.local_rows();
                let off = slab.row_offset();
                for r in 0..lr {
                    for c in 0..4 {
                        assert_eq!(
                            slab.data[r * 4 + c],
                            whole.data[(off + r) * 4 + c],
                            "parts={parts} rank={rank} r={r} c={c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn extract_chunk_is_column_block() {
        let mut slab = Slab::zeroed(4, 8, 2, 0);
        for r in 0..2 {
            for c in 0..8 {
                slab.data[r * 8 + c] = Complex32::new((r * 8 + c) as f32, 0.0);
            }
        }
        let chunk1 = slab.extract_chunk(1); // columns 4..8
        let expect: Vec<f32> = vec![4.0, 5.0, 6.0, 7.0, 12.0, 13.0, 14.0, 15.0];
        assert_eq!(chunk1.iter().map(|c| c.re).collect::<Vec<_>>(), expect);
        assert_eq!(chunk1.len(), 2 * 4);
    }

    #[test]
    fn banded_extraction_concatenates_to_whole_chunk() {
        let slab = Slab::synthetic(12, 24, 4, 1);
        let lr = slab.local_rows();
        for j in 0..4 {
            let whole = slab.extract_chunk_bytes(j);
            for band in [1usize, 2, 3] {
                let mut pieces = Vec::new();
                let mut r = 0;
                while r < lr {
                    let hi = (r + band).min(lr);
                    pieces.extend_from_slice(&Slab::extract_chunk_rows_bytes(
                        &slab.data, 24, 4, j, r, hi,
                    ));
                    r = hi;
                }
                assert_eq!(pieces, whole, "chunk {j}, band {band}");
            }
        }
    }

    #[test]
    fn bytes_sent_matches_formula() {
        let slab = Slab::zeroed(16, 16, 4, 0);
        // local slab = 4×16×8 = 512 bytes; (1 - 1/4) = 384.
        assert_eq!(slab.bytes_sent_per_locality(), 384);
    }

    #[test]
    fn real_synthetic_is_decomposition_independent() {
        let whole = RealSlab::whole(8, 6);
        for parts in [2usize, 4] {
            for rank in 0..parts {
                let slab = RealSlab::synthetic(8, 6, parts, rank);
                let off = slab.row_offset();
                for r in 0..slab.local_rows() {
                    for c in 0..6 {
                        assert_eq!(
                            slab.data[r * 6 + c],
                            whole.data[(off + r) * 6 + c],
                            "parts={parts} rank={rank} r={r} c={c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn real_input_spectral_geometry() {
        let slab = RealSlab::synthetic(16, 24, 4, 1);
        assert_eq!(slab.local_rows(), 4);
        assert_eq!(slab.packed_cols(), 12);
        let input = FftInput::Real(&slab);
        assert_eq!(input.spectral_cols(), 12);
        assert_eq!(input.global_rows(), 16);
        assert_eq!(input.local_rows(), 4);
        assert_eq!(input.stage1_seed().len(), 4 * 12);

        let cslab = Slab::synthetic(16, 24, 4, 1);
        let cinput = FftInput::Complex(&cslab);
        assert_eq!(cinput.spectral_cols(), 24);
        assert_eq!(cinput.rank(), 1);
        assert_eq!(cinput.parts(), 4);
    }

    #[test]
    #[should_panic(expected = "must be even")]
    fn odd_real_cols_rejected_for_packing() {
        RealSlab::synthetic(4, 5, 1, 0).packed_cols();
    }

    #[test]
    fn real_stage1_band_matches_whole_sweep() {
        use crate::dist_fft::driver::NativeRowFft;
        let slab = RealSlab::synthetic(12, 8, 2, 0);
        let input = FftInput::Real(&slab);
        let lr = input.local_rows();
        let mut whole = input.stage1_seed();
        input.stage1_band(&mut whole, 0, lr, &NativeRowFft, 1);
        for band in [1usize, 2, 4] {
            let mut banded = input.stage1_seed();
            let mut r = 0;
            while r < lr {
                let hi = (r + band).min(lr);
                input.stage1_band(&mut banded, r, hi, &NativeRowFft, 2);
                r = hi;
            }
            assert_eq!(banded, whole, "band {band}");
        }
    }

    #[test]
    fn row_offsets_tile_the_grid() {
        let parts = 4;
        let mut covered = vec![false; 16];
        for rank in 0..parts {
            let slab = Slab::zeroed(16, 4, parts, rank);
            for r in 0..slab.local_rows() {
                covered[slab.row_offset() + r] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }
}
