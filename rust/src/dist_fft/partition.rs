//! Slab decomposition of the global 2-D grid.

use crate::fft::complex::Complex32;
use crate::util::rng::Pcg32;

/// One locality's row-slab of the global `R × C` grid.
#[derive(Clone, Debug)]
pub struct Slab {
    /// Global grid rows.
    pub global_rows: usize,
    /// Global grid cols.
    pub global_cols: usize,
    /// Number of participating localities.
    pub parts: usize,
    /// Which slab this is.
    pub rank: usize,
    /// Row-major local data, `local_rows() × global_cols`.
    pub data: Vec<Complex32>,
}

impl Slab {
    /// Rows held by each locality. `R` must divide evenly by the
    /// locality count; beyond that any length is fine — the mixed-radix
    /// planner handles non-power-of-two rows (the paper's own grids are
    /// `2^k` on power-of-two node counts, the conservative special case).
    pub fn rows_per_part(global_rows: usize, parts: usize) -> usize {
        assert!(parts > 0, "need at least one part");
        assert!(
            global_rows % parts == 0,
            "global rows {global_rows} not divisible by {parts} localities"
        );
        global_rows / parts
    }

    /// Columns per all-to-all chunk (`C` must divide evenly).
    pub fn cols_per_chunk(global_cols: usize, parts: usize) -> usize {
        assert!(
            global_cols % parts == 0,
            "global cols {global_cols} not divisible by {parts} localities"
        );
        global_cols / parts
    }

    /// Rows in this slab.
    pub fn local_rows(&self) -> usize {
        Self::rows_per_part(self.global_rows, self.parts)
    }

    /// First global row of this slab.
    pub fn row_offset(&self) -> usize {
        self.rank * self.local_rows()
    }

    /// Allocate a zeroed slab.
    pub fn zeroed(global_rows: usize, global_cols: usize, parts: usize, rank: usize) -> Self {
        assert!(rank < parts, "rank {rank} out of range");
        let local_rows = Self::rows_per_part(global_rows, parts);
        Self {
            global_rows,
            global_cols,
            parts,
            rank,
            data: vec![Complex32::ZERO; local_rows * global_cols],
        }
    }

    /// Deterministic synthetic signal: every locality can generate its
    /// slab independently, and a serial process can generate the whole
    /// grid bit-identically (verification depends on this).
    pub fn synthetic(global_rows: usize, global_cols: usize, parts: usize, rank: usize) -> Self {
        let mut slab = Self::zeroed(global_rows, global_cols, parts, rank);
        let local_rows = slab.local_rows();
        let row0 = slab.row_offset();
        for r in 0..local_rows {
            let grow = row0 + r;
            // One RNG stream per global row → decomposition-independent.
            let mut rng = Pcg32::with_stream(0x0B5E_2411, grow as u64 + 1);
            for c in 0..global_cols {
                slab.data[r * global_cols + c] = Complex32::new(rng.next_signal(), rng.next_signal());
            }
        }
        slab
    }

    /// The whole global grid as one slab (serial reference).
    pub fn whole(global_rows: usize, global_cols: usize) -> Self {
        Self::synthetic(global_rows, global_cols, 1, 0)
    }

    /// Extract the column-block chunk destined for locality `j` as a
    /// contiguous row-major `local_rows × cols_per_chunk` buffer.
    pub fn extract_chunk(&self, j: usize) -> Vec<Complex32> {
        let lr = self.local_rows();
        let cw = Self::cols_per_chunk(self.global_cols, self.parts);
        let c0 = j * cw;
        let mut out = Vec::with_capacity(lr * cw);
        for r in 0..lr {
            let base = r * self.global_cols + c0;
            out.extend_from_slice(&self.data[base..base + cw]);
        }
        out
    }

    /// Extract the chunk for locality `j` directly as a wire-format byte
    /// buffer — one pass, one allocation (§Perf: replaces
    /// `extract_chunk` + re-serialization on the send path).
    pub fn extract_chunk_bytes(&self, j: usize) -> Vec<u8> {
        let lr = self.local_rows();
        let cw = Self::cols_per_chunk(self.global_cols, self.parts);
        let c0 = j * cw;
        let mut out = Vec::with_capacity(lr * cw * std::mem::size_of::<Complex32>());
        for r in 0..lr {
            let base = r * self.global_cols + c0;
            out.extend_from_slice(crate::fft::complex::as_byte_slice(
                &self.data[base..base + cw],
            ));
        }
        out
    }

    /// Extract rows `[r0, r1)` of the column-block chunk destined for
    /// locality `j` from a working buffer (`local_rows × global_cols`,
    /// row-major) as wire-format bytes — the banded variant of
    /// [`Slab::extract_chunk_bytes`]. The async FFT driver uses this to
    /// post a wire chunk the moment the rows feeding it finish their
    /// first-dimension FFT, while later rows are still being transformed.
    pub fn extract_chunk_rows_bytes(
        data: &[crate::fft::complex::Complex32],
        global_cols: usize,
        parts: usize,
        j: usize,
        r0: usize,
        r1: usize,
    ) -> Vec<u8> {
        let cw = Self::cols_per_chunk(global_cols, parts);
        let c0 = j * cw;
        assert!(r0 <= r1, "inverted row band [{r0}, {r1})");
        assert!(r1 * global_cols <= data.len(), "band exceeds buffer");
        let mut out =
            Vec::with_capacity((r1 - r0) * cw * std::mem::size_of::<Complex32>());
        for r in r0..r1 {
            let base = r * global_cols + c0;
            out.extend_from_slice(crate::fft::complex::as_byte_slice(
                &data[base..base + cw],
            ));
        }
        out
    }

    /// Bytes a locality sends during the communication step:
    /// `(1 − 1/N)` of its slab, 8 bytes per complex element.
    pub fn bytes_sent_per_locality(&self) -> usize {
        let total = self.local_rows() * self.global_cols * std::mem::size_of::<Complex32>();
        total - total / self.parts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_divide() {
        assert_eq!(Slab::rows_per_part(16, 4), 4);
        assert_eq!(Slab::rows_per_part(16, 1), 16);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn ragged_rows_rejected() {
        Slab::rows_per_part(10, 4);
    }

    #[test]
    fn synthetic_is_decomposition_independent() {
        let whole = Slab::whole(8, 4);
        for parts in [2usize, 4] {
            for rank in 0..parts {
                let slab = Slab::synthetic(8, 4, parts, rank);
                let lr = slab.local_rows();
                let off = slab.row_offset();
                for r in 0..lr {
                    for c in 0..4 {
                        assert_eq!(
                            slab.data[r * 4 + c],
                            whole.data[(off + r) * 4 + c],
                            "parts={parts} rank={rank} r={r} c={c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn extract_chunk_is_column_block() {
        let mut slab = Slab::zeroed(4, 8, 2, 0);
        for r in 0..2 {
            for c in 0..8 {
                slab.data[r * 8 + c] = Complex32::new((r * 8 + c) as f32, 0.0);
            }
        }
        let chunk1 = slab.extract_chunk(1); // columns 4..8
        let expect: Vec<f32> = vec![4.0, 5.0, 6.0, 7.0, 12.0, 13.0, 14.0, 15.0];
        assert_eq!(chunk1.iter().map(|c| c.re).collect::<Vec<_>>(), expect);
        assert_eq!(chunk1.len(), 2 * 4);
    }

    #[test]
    fn banded_extraction_concatenates_to_whole_chunk() {
        let slab = Slab::synthetic(12, 24, 4, 1);
        let lr = slab.local_rows();
        for j in 0..4 {
            let whole = slab.extract_chunk_bytes(j);
            for band in [1usize, 2, 3] {
                let mut pieces = Vec::new();
                let mut r = 0;
                while r < lr {
                    let hi = (r + band).min(lr);
                    pieces.extend_from_slice(&Slab::extract_chunk_rows_bytes(
                        &slab.data, 24, 4, j, r, hi,
                    ));
                    r = hi;
                }
                assert_eq!(pieces, whole, "chunk {j}, band {band}");
            }
        }
    }

    #[test]
    fn bytes_sent_matches_formula() {
        let slab = Slab::zeroed(16, 16, 4, 0);
        // local slab = 4×16×8 = 512 bytes; (1 - 1/4) = 384.
        assert_eq!(slab.bytes_sent_per_locality(), 384);
    }

    #[test]
    fn row_offsets_tile_the_grid() {
        let parts = 4;
        let mut covered = vec![false; 16];
        for rank in 0..parts {
            let slab = Slab::zeroed(16, 4, parts, rank);
            for r in 0..slab.local_rows() {
                covered[slab.row_offset() + r] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }
}
