//! Distributed 3-D FFT over a pencil decomposition — the `dist_fft`
//! subsystem the paper's 2-D slab benchmark generalizes to.
//!
//! The global `n0 × n1 × n2` grid lives on a `Pr × Pc` process grid of
//! localities ([`Grid3`] / [`ProcGrid`]); each locality executes five
//! phases:
//!
//! 1. **FFT(z)** over its z-pencils (rows of length `n2`),
//! 2. **transpose 1**: all-to-all *within its row communicator* (the
//!    `Pc` localities sharing its process-grid row) — z-pencils become
//!    y-pencils,
//! 3. **FFT(y)** (rows of length `n1`),
//! 4. **transpose 2**: all-to-all *within its column communicator* (the
//!    `Pr` localities sharing its process-grid column) — y-pencils
//!    become x-pencils,
//! 5. **FFT(x)** (rows of length `n0`).
//!
//! The result is the 3-D FFT in transposed distributed layout
//! (`[i2][i1][i0]`, the 3-D analogue of `FFTW_MPI_TRANSPOSED_OUT`).
//!
//! The row/column communicators come from [`Communicator::split`] — the
//! communicator-splitting capability this subsystem motivated — so both
//! exchanges run the chunked known-size wire protocol on *disjoint tag
//! spaces with their own send pools*, and arriving wire chunks are
//! transpose-placed the moment they land
//! ([`grid3::place_t1_slice`] / [`grid3::place_t2_slice`]).
//!
//! Both [`ExecutionMode`]s are supported: *blocking* settles each
//! round's sends before the next FFT phase; *async* lets them keep
//! draining through the sub-communicators' send pools underneath the
//! following FFT phases (the futures engine of PR 3) and reports the
//! hidden wall time as [`PencilTimings::overlap_us`]. Both modes perform
//! identical arithmetic, so their results — like the results across
//! parcelports — are bitwise identical.

use super::driver::{ComputeEngine, Domain, ExecutionMode, RowFft};
use super::grid3::{self, Grid3, PencilDims, ProcGrid};
use super::scatter_variant::hidden_us;
use super::verify::rel_error;
use crate::fft::real::rfft_rows_packed_into;
use crate::collectives::{ChunkPolicy, Communicator};
use crate::fft::complex::{from_le_bytes, Complex32};
use crate::hpx::parcel::Payload;
use crate::hpx::runtime::Cluster;
use crate::parcelport::{NetModel, PortKind};
use crate::task::TaskFuture;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Full configuration of one distributed 3-D pencil FFT execution.
#[derive(Clone, Debug)]
pub struct Pencil3Config {
    /// Global grid extents (`--grid3`). Constraints: `Pr | n0`,
    /// `Pr | n1`, `Pc | n1`, `Pc | n2`.
    pub grid: Grid3,
    /// Process grid (`--proc-grid`); `pr·pc` localities are used.
    pub proc: ProcGrid,
    /// Parcelport backend.
    pub port: PortKind,
    /// Wire-chunking policy for both transpose rounds (inherited by the
    /// row/column sub-communicators at split time).
    pub chunk: ChunkPolicy,
    /// Lock-step rounds vs the future-chained task graph (`--exec`).
    pub exec: ExecutionMode,
    /// Input domain (`--domain`): complex z-pencils, or real input
    /// whose phase-1 r2c packs each z-row into `n2/2` bins — both
    /// transpose rounds then run on the halved grid, moving half the
    /// wire bytes. Real grids need an even `n2` with `n2/2` divisible
    /// by `Pc`, and the native engine.
    pub domain: Domain,
    /// Worker threads per locality for the row-FFT phases.
    pub threads_per_locality: usize,
    /// Optional hybrid wire model.
    pub net: Option<NetModel>,
    /// Row-FFT compute engine.
    pub engine: ComputeEngine,
    /// Compare the distributed result against the serial reference.
    pub verify: bool,
}

impl Default for Pencil3Config {
    fn default() -> Self {
        Self {
            grid: Grid3::new(32, 32, 32),
            proc: ProcGrid::new(2, 2),
            port: PortKind::Lci,
            chunk: ChunkPolicy::default(),
            exec: ExecutionMode::Blocking,
            domain: Domain::Complex,
            threads_per_locality: 2,
            net: None,
            engine: ComputeEngine::Native,
            verify: true,
        }
    }
}

impl Pencil3Config {
    /// The execution settings this config shares with every other
    /// transform shape, as a [`crate::config::TransformSpec`].
    pub fn spec(&self) -> crate::config::TransformSpec {
        crate::config::TransformSpec {
            port: self.port,
            chunk: self.chunk,
            exec: self.exec,
            domain: self.domain,
            threads_per_locality: self.threads_per_locality,
            net: self.net,
            engine: self.engine.clone(),
            verify: self.verify,
        }
    }

    /// Overwrite the shared execution settings from a
    /// [`crate::config::TransformSpec`], leaving the 3-D shape fields
    /// (`grid`/`proc`) untouched.
    pub fn apply_spec(&mut self, spec: &crate::config::TransformSpec) {
        self.port = spec.port;
        self.chunk = spec.chunk;
        self.exec = spec.exec;
        self.domain = spec.domain;
        self.threads_per_locality = spec.threads_per_locality;
        self.net = spec.net;
        self.engine = spec.engine.clone();
        self.verify = spec.verify;
    }
}

/// Per-phase wall-clock timings (µs) for one locality.
#[derive(Clone, Copy, Debug, Default)]
pub struct PencilTimings {
    /// Phase-1 z-row FFTs (length `n2`).
    pub fft_z_us: f64,
    /// Wall time of the round-1 (row-communicator) exchange. Blocking:
    /// includes settling this rank's sends. Async: closes when receives
    /// are in *and* the round's sends have drained (which may be after
    /// later phases — that is the overlap).
    pub t1_comm_us: f64,
    /// Time spent transpose-placing round-1 chunks (overlapped inside
    /// `t1_comm_us`).
    pub t1_place_us: f64,
    /// Phase-3 y-row FFTs (length `n1`).
    pub fft_y_us: f64,
    /// Wall time of the round-2 (column-communicator) exchange.
    pub t2_comm_us: f64,
    /// Time spent transpose-placing round-2 chunks.
    pub t2_place_us: f64,
    /// Phase-5 x-row FFTs (length `n0`).
    pub fft_x_us: f64,
    /// Compute wall time that executed while collective traffic was
    /// still in flight (on-arrival placements plus the slices of the
    /// y/x FFT phases that ran before the preceding round's sends
    /// drained). Always 0 in blocking mode.
    pub overlap_us: f64,
    /// End-to-end wall time of the five phases.
    pub total_us: f64,
}

impl PencilTimings {
    /// Element-wise max across localities — the critical path.
    pub fn max(timings: &[PencilTimings]) -> PencilTimings {
        let mut out = PencilTimings::default();
        for t in timings {
            out.fft_z_us = out.fft_z_us.max(t.fft_z_us);
            out.t1_comm_us = out.t1_comm_us.max(t.t1_comm_us);
            out.t1_place_us = out.t1_place_us.max(t.t1_place_us);
            out.fft_y_us = out.fft_y_us.max(t.fft_y_us);
            out.t2_comm_us = out.t2_comm_us.max(t.t2_comm_us);
            out.t2_place_us = out.t2_place_us.max(t.t2_place_us);
            out.fft_x_us = out.fft_x_us.max(t.fft_x_us);
            out.overlap_us = out.overlap_us.max(t.overlap_us);
            out.total_us = out.total_us.max(t.total_us);
        }
        out
    }
}

/// Execution report of one 3-D pencil FFT.
#[derive(Clone, Debug)]
pub struct Pencil3Report {
    /// One-line description of the executed configuration.
    pub config_summary: String,
    /// Per-locality phase timings, rank order.
    pub per_rank: Vec<PencilTimings>,
    /// Element-wise max across localities.
    pub critical_path: PencilTimings,
    /// Relative L2 error vs. the serial reference (if verified).
    pub rel_error: Option<f64>,
    /// Traffic accounted by the parcelport during the run.
    pub stats: crate::parcelport::PortStatsSnapshot,
}

/// Outcome of one transpose round's exchange (sends may still be
/// outstanding in async mode).
struct RoundOutcome {
    /// Instant the first byte could have entered the wire.
    open: Instant,
    /// Instant the last expected wire chunk was placed.
    recv_done: Instant,
    /// Total on-arrival placement time, µs.
    place_us: f64,
    /// The slice of `place_us` performed inside the open comm window —
    /// every on-arrival placement (receives from other peers are still
    /// outstanding while it runs), plus the own-block placement whenever
    /// wire chunks were actually posted. Counted into
    /// [`PencilTimings::overlap_us`] in async mode.
    in_flight_us: f64,
    /// Outstanding send-completion futures.
    sends: Vec<TaskFuture<()>>,
}

/// One transpose round over `comm`: post this rank's per-peer chunks as
/// known-size pipelined wire chunks through the communicator's send
/// pool, then place every arriving wire chunk (own chunk included, first)
/// as soon as it lands. `extract` produces a peer's wire-format chunk;
/// `extract_own` produces this rank's own block as elements — it never
/// touches the fabric, so it skips the wire byte round-trip. Never
/// settles the sends — the caller decides whether to block on them
/// (blocking mode) or let them drain under the next FFT phase (async
/// mode). Each send completion stamps `last_send_done`. `round` labels
/// this exchange's placement spans on a traced timeline (`"t1"`/`"t2"`).
fn exchange_round(
    comm: &Communicator,
    round: &'static str,
    chunk_elems: usize,
    mut extract: impl FnMut(usize) -> Vec<u8>,
    extract_own: impl FnOnce(usize) -> Vec<Complex32>,
    mut place: impl FnMut(usize, usize, &[Complex32]),
    last_send_done: &Arc<Mutex<Option<Instant>>>,
) -> RoundOutcome {
    const ELEM: usize = std::mem::size_of::<Complex32>();
    let n = comm.size();
    let me = comm.rank();
    let policy = comm.chunk_policy();
    let tags = comm.scatter_chunk_tags(n);
    let wire_chunks = policy.n_chunks(chunk_elems * ELEM);

    let open = Instant::now();
    let mut sends = Vec::new();
    for dst in 0..n {
        if dst == me {
            continue;
        }
        for f in comm.send_chunked_sized(dst, tags[me], Payload::new(extract(dst))) {
            let stamp = Arc::clone(last_send_done);
            f.then_inline(move |_| {
                *stamp.lock().unwrap() = Some(Instant::now());
            });
            sends.push(f);
        }
    }

    let mut place_us = 0.0f64;
    let mut in_flight_us = 0.0f64;
    // Own chunk is "received" immediately — place it first (free overlap
    // while the posted wire chunks fly).
    {
        let tt = Instant::now();
        let _span = crate::obs::span_args(
            "place",
            round,
            comm.my_global(),
            me as i64,
            crate::obs::NO_ARG,
            crate::obs::NO_ARG,
        );
        let own = extract_own(me);
        place(me, 0, &own);
        let us = tt.elapsed().as_secs_f64() * 1e6;
        place_us += us;
        if n > 1 {
            in_flight_us += us;
        }
    }

    // Poll the peers; place whichever wire chunk lands first, consuming
    // each peer's chunks in offset order.
    let mut pending: Vec<(usize, usize)> = // (peer, next wire-chunk index)
        (0..n).filter(|&r| r != me).map(|peer| (peer, 0)).collect();
    while !pending.is_empty() {
        let mut progressed = false;
        let mut i = 0;
        while i < pending.len() {
            let (peer, next_chunk) = &mut pending[i];
            while *next_chunk < wire_chunks {
                let Some(payload) = comm.try_recv_chunk(*peer, tags[*peer], *next_chunk)
                else {
                    break;
                };
                let tt = Instant::now();
                let span = crate::obs::span_args(
                    "place",
                    round,
                    comm.my_global(),
                    *peer as i64,
                    *next_chunk as i64,
                    payload.len() as i64,
                );
                let elems = from_le_bytes(payload.as_bytes());
                place(*peer, *next_chunk * policy.chunk_bytes / ELEM, &elems);
                drop(span);
                let us = tt.elapsed().as_secs_f64() * 1e6;
                place_us += us;
                in_flight_us += us;
                *next_chunk += 1;
                progressed = true;
            }
            if *next_chunk >= wire_chunks {
                pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if !progressed {
            std::hint::spin_loop();
            std::thread::yield_now();
        }
    }

    RoundOutcome { open, recv_done: Instant::now(), place_us, in_flight_us, sends }
}

/// Settle outstanding sends and return the stamped completion instant
/// (falling back to `fallback` when there were none).
fn settle_sends(
    sends: Vec<TaskFuture<()>>,
    last_send_done: &Arc<Mutex<Option<Instant>>>,
    fallback: Instant,
) -> Instant {
    for f in sends {
        f.get();
    }
    last_send_done.lock().unwrap().take().unwrap_or(fallback)
}

/// The per-rank five-phase pencil pipeline over an arbitrary
/// communicator of `proc.n()` ranks — the cluster driver hands it the
/// world communicator, [`crate::runtime::FftService`] a per-job
/// sub-communicator. `dims_in` is the input-side decomposition (the
/// real z-extent in the real domain); `dims` is the *spectral*
/// decomposition every phase after the z transform runs on — identical
/// to `dims_in` in the complex domain, the `n2/2`-packed grid in the
/// real domain.
pub(crate) fn run_rank(
    world: &Communicator,
    dims_in: &PencilDims,
    dims: &PencilDims,
    config: &Pencil3Config,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, PencilTimings) {
    const ELEM: usize = std::mem::size_of::<Complex32>();
    let nthreads = config.threads_per_locality;
    // Typed payloads: wire chunks must never split a complex element.
    world.set_chunk_policy(config.chunk.aligned(ELEM));
    let (row_idx, col_idx) = dims.proc.coords(world.rank());
    // Row communicator: the Pc localities of my process-grid row,
    // ordered by column. Column communicator: the Pr localities of my
    // column, ordered by row. Disjoint tag spaces + own send pools.
    let row_comm = world.split(row_idx as u64, col_idx as u64);
    let col_comm = world.split(col_idx as u64, row_idx as u64);
    row_comm.warm_chunk_pool();
    col_comm.warm_chunk_pool();

    let async_mode = config.exec == ExecutionMode::Async;
    let mut t = PencilTimings::default();
    // Input generation happens outside the timed window, like the 2-D
    // variants (whose slabs are synthesized before their `run`); the
    // phase-1 transform (c2c sweep, or the r2c pack) is inside it.
    let (real_src, mut zbuf) = match config.domain {
        Domain::Complex => (None, grid3::synthetic_pencil(dims, row_idx, col_idx)),
        Domain::Real => (
            Some(grid3::synthetic_pencil_real(dims_in, row_idx, col_idx)),
            vec![Complex32::ZERO; dims.local_elems()],
        ),
    };
    let t_start = Instant::now();

    // Phase 1: FFT(z) — r2c-packed into n2/2 bins in the real domain.
    let t0 = Instant::now();
    {
        let _span = crate::obs::span("fft", "z", world.my_global());
        match &real_src {
            None => engine.fft_rows(&mut zbuf, dims.grid.n2, nthreads),
            Some(src) => rfft_rows_packed_into(src, dims_in.grid.n2, &mut zbuf, nthreads),
        }
    }
    t.fft_z_us = t0.elapsed().as_secs_f64() * 1e6;

    // Phase 2: transpose 1 over the row communicator.
    let mut ybuf = vec![Complex32::ZERO; dims.d0 * dims.d2c * dims.grid.n1];
    let last1: Arc<Mutex<Option<Instant>>> = Arc::new(Mutex::new(None));
    let mut o1 = exchange_round(
        &row_comm,
        "t1",
        dims.t1_chunk_elems(),
        |dest| grid3::extract_t1_bytes(&zbuf, dims, dest),
        |me| grid3::extract_t1_elems(&zbuf, dims, me),
        |src, off, elems| grid3::place_t1_slice(elems, off, dims, &mut ybuf, src),
        &last1,
    );
    t.t1_place_us = o1.place_us;
    drop(zbuf);
    if async_mode {
        t.overlap_us += o1.in_flight_us; // settled after the last phase
    } else {
        let done = settle_sends(std::mem::take(&mut o1.sends), &last1, o1.recv_done);
        t.t1_comm_us =
            o1.recv_done.max(done).duration_since(o1.open).as_secs_f64() * 1e6;
    }

    // Phase 3: FFT(y) — in async mode round-1 sends keep draining
    // underneath this.
    let ty0 = Instant::now();
    {
        let _span = crate::obs::span("fft", "y", world.my_global());
        engine.fft_rows(&mut ybuf, dims.grid.n1, nthreads);
    }
    let ty1 = Instant::now();
    t.fft_y_us = ty1.duration_since(ty0).as_secs_f64() * 1e6;

    // Phase 4: transpose 2 over the column communicator.
    let mut xbuf = vec![Complex32::ZERO; dims.d2c * dims.d1r * dims.grid.n0];
    let last2: Arc<Mutex<Option<Instant>>> = Arc::new(Mutex::new(None));
    let mut o2 = exchange_round(
        &col_comm,
        "t2",
        dims.t2_chunk_elems(),
        |dest| grid3::extract_t2_bytes(&ybuf, dims, dest),
        |me| grid3::extract_t2_elems(&ybuf, dims, me),
        |src, off, elems| grid3::place_t2_slice(elems, off, dims, &mut xbuf, src),
        &last2,
    );
    t.t2_place_us = o2.place_us;
    drop(ybuf);
    if async_mode {
        t.overlap_us += o2.in_flight_us;
    } else {
        let done = settle_sends(std::mem::take(&mut o2.sends), &last2, o2.recv_done);
        t.t2_comm_us =
            o2.recv_done.max(done).duration_since(o2.open).as_secs_f64() * 1e6;
    }

    // Phase 5: FFT(x) — in async mode both rounds' send tails may still
    // be draining here.
    let tx0 = Instant::now();
    {
        let _span = crate::obs::span("fft", "x", world.my_global());
        engine.fft_rows(&mut xbuf, dims.grid.n0, nthreads);
    }
    let tx1 = Instant::now();
    t.fft_x_us = tx1.duration_since(tx0).as_secs_f64() * 1e6;

    if async_mode {
        // Settle both rounds only now; the send tails were hidden behind
        // the y/x FFT phases.
        let s1 = settle_sends(std::mem::take(&mut o1.sends), &last1, o1.recv_done);
        let s2 = settle_sends(std::mem::take(&mut o2.sends), &last2, o2.recv_done);
        t.t1_comm_us = o1.recv_done.max(s1).duration_since(o1.open).as_secs_f64() * 1e6;
        t.t2_comm_us = o2.recv_done.max(s2).duration_since(o2.open).as_secs_f64() * 1e6;
        // Round-2 traffic is not posted yet while FFT(y) runs, so its
        // hidden window is judged against round 1's drain only; FFT(x)
        // can hide behind either round's tail.
        t.overlap_us += hidden_us(ty0, ty1, s1);
        t.overlap_us += hidden_us(tx0, tx1, s1.max(s2));
    }
    t.total_us = t_start.elapsed().as_secs_f64() * 1e6;
    (xbuf, t)
}

/// Run one distributed 3-D pencil FFT end to end on a fresh cluster.
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `grid3` and call `Transform::run` \
            instead"
)]
pub fn run(config: &Pencil3Config) -> anyhow::Result<Pencil3Report> {
    let cluster = Cluster::new(config.proc.n(), config.port, config.net)?;
    Ok(run_on_collect(&cluster, config)?.0)
}

/// Run on an existing cluster (benchmarks reuse fabrics across reps).
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `grid3` and call `Transform::run_on` \
            instead"
)]
pub fn run_on(cluster: &Cluster, config: &Pencil3Config) -> anyhow::Result<Pencil3Report> {
    Ok(run_on_collect(cluster, config)?.0)
}

/// Validate everything about a 3-D configuration that does not require
/// a live cluster, returning the input-side and spectral
/// decompositions. Shared by the deprecated pencil shims,
/// [`TransformRequest::build`], and the service's job admission, so the
/// actionable error strings are identical on every entry path.
///
/// [`TransformRequest::build`]: super::TransformRequest::build
pub(crate) fn validate_config(
    config: &Pencil3Config,
) -> anyhow::Result<(PencilDims, PencilDims)> {
    // Real-domain preconditions come first: PencilDims::new would
    // otherwise report a generic odd-n2 divisibility error before the
    // r2c-specific message could fire.
    if config.domain == Domain::Real {
        anyhow::ensure!(
            config.grid.n2 % 2 == 0,
            "real-domain pencil grids need an even z-extent (r2c packs \
             the half-spectrum into n2/2 bins), got n2 = {}",
            config.grid.n2
        );
        anyhow::ensure!(
            matches!(config.engine, ComputeEngine::Native),
            "real-domain runs require the native compute engine"
        );
    }
    let dims_in = PencilDims::new(config.grid, config.proc)?;
    // The spectral decomposition phases 2–5 run on: identical to the
    // input decomposition in the complex domain; the z-halved packed
    // grid in the real domain.
    let dims = match config.domain {
        Domain::Complex => dims_in,
        Domain::Real => PencilDims::new(
            Grid3::new(config.grid.n0, config.grid.n1, config.grid.n2 / 2),
            config.proc,
        )
        .map_err(|e| e.context("real-domain packed (n2/2) spectral grid"))?,
    };
    config.chunk.validate()?;
    Ok((dims_in, dims))
}

/// Run on an existing cluster, additionally returning each rank's
/// stage-X pencil — the engine behind the deprecated shims and
/// [`Transform::run_on`]; tests use the raw pieces for
/// bitwise-stability checks across ports and execution modes.
///
/// [`Transform::run_on`]: super::Transform::run_on
pub fn run_on_collect(
    cluster: &Cluster,
    config: &Pencil3Config,
) -> anyhow::Result<(Pencil3Report, Vec<Vec<Complex32>>)> {
    let (dims_in, dims) = validate_config(config)?;
    anyhow::ensure!(
        cluster.n_localities() == config.proc.n(),
        "cluster size mismatch: {} vs {} ({} process grid)",
        cluster.n_localities(),
        config.proc.n(),
        config.proc
    );
    let engine = config.engine.build()?;
    let before = cluster.fabric().stats();

    let results: Vec<(Vec<Complex32>, PencilTimings)> = cluster.run(|ctx| {
        let world = Communicator::from_ctx(ctx);
        run_rank(&world, &dims_in, &dims, config, engine.as_ref())
    });

    let stats = cluster.fabric().stats().since(&before);
    let per_rank: Vec<PencilTimings> = results.iter().map(|(_, t)| *t).collect();
    let critical_path = PencilTimings::max(&per_rank);
    let pieces: Vec<Vec<Complex32>> = results.into_iter().map(|(p, _)| p).collect();

    let rel_err = if config.verify { Some(verify_pieces(config, &dims, &pieces)) } else { None };

    let report = Pencil3Report {
        config_summary: summary_line(config, engine.name()),
        per_rank,
        critical_path,
        rel_error: rel_err,
        stats,
    };
    Ok((report, pieces))
}

/// Relative L2 error of assembled per-rank pencils vs. the serial
/// reference for this configuration's synthetic input. `dims` is the
/// spectral decomposition from [`validate_config`].
pub(crate) fn verify_pieces(
    config: &Pencil3Config,
    dims: &PencilDims,
    pieces: &[Vec<Complex32>],
) -> f64 {
    let mut assembled = Vec::with_capacity(dims.grid.elems());
    for piece in pieces {
        assembled.extend_from_slice(piece);
    }
    let reference = match config.domain {
        Domain::Complex => {
            super::verify::serial_fft3_transposed(&grid3::whole_grid(config.grid), config.grid)
        }
        Domain::Real => super::verify::serial_rfft3_packed_transposed(
            &grid3::whole_grid_real(config.grid),
            config.grid,
        ),
    };
    let expected = distribute_transposed(&reference, dims);
    rel_error(&assembled, &expected)
}

/// One-line human description of an executed configuration.
pub(crate) fn summary_line(config: &Pencil3Config, engine_name: &str) -> String {
    format!(
        "{} grid, {} process grid, {} port, {} exec, {} domain, {} engine",
        config.grid,
        config.proc,
        config.port,
        config.exec.name(),
        config.domain.name(),
        engine_name,
    )
}

/// Reorder a global transposed-layout reference (`[i2][i1][i0]`) into
/// the concatenation of per-rank stage-X pencils, rank order — the shape
/// a distributed run assembles into.
pub fn distribute_transposed(reference: &[Complex32], dims: &PencilDims) -> Vec<Complex32> {
    let grid = dims.grid;
    assert_eq!(reference.len(), grid.elems(), "reference shape mismatch");
    let mut out = Vec::with_capacity(grid.elems());
    for rank in 0..dims.proc.n() {
        let (ri, ci) = dims.proc.coords(rank);
        for s in 0..dims.d2c {
            let i2 = ci * dims.d2c + s;
            for r in 0..dims.d1r {
                let i1 = ri * dims.d1r + r;
                let base = (i2 * grid.n1 + i1) * grid.n0;
                out.extend_from_slice(&reference[base..base + grid.n0]);
            }
        }
    }
    out
}

#[cfg(test)]
// Exercises the deprecated `run`/`run_on` shims on purpose — shim
// coverage until every external caller has migrated to
// `TransformRequest`.
#[allow(deprecated)]
mod tests {
    use super::*;

    fn acceptance_config(pr: usize, pc: usize) -> Pencil3Config {
        Pencil3Config {
            grid: Grid3::new(12, 8, 24),
            proc: ProcGrid::new(pr, pc),
            threads_per_locality: 1,
            ..Default::default()
        }
    }

    #[test]
    fn default_config_runs_and_verifies() {
        let report = run(&Pencil3Config {
            grid: Grid3::new(16, 16, 16),
            ..Default::default()
        })
        .unwrap();
        assert!(report.rel_error.unwrap() < 1e-4, "{:?}", report.rel_error);
        assert_eq!(report.per_rank.len(), 4);
        assert!(report.critical_path.total_us > 0.0);
        assert!(report.stats.msgs_sent > 0);
    }

    #[test]
    fn all_proc_shapes_verify_non_pow2() {
        for (pr, pc) in [(1, 4), (2, 2), (4, 1)] {
            let report = run(&acceptance_config(pr, pc)).unwrap();
            assert!(
                report.rel_error.unwrap() < 1e-4,
                "{pr}x{pc}: {:?}",
                report.rel_error
            );
        }
    }

    #[test]
    fn async_mode_verifies_and_matches_blocking_bitwise() {
        for (pr, pc) in [(2, 2), (4, 1)] {
            let run_mode = |exec: ExecutionMode| {
                let cfg = Pencil3Config {
                    exec,
                    chunk: ChunkPolicy::new(256, 2),
                    ..acceptance_config(pr, pc)
                };
                let cluster = Cluster::new(cfg.proc.n(), cfg.port, cfg.net).unwrap();
                let dims = PencilDims::new(cfg.grid, cfg.proc).unwrap();
                let engine = cfg.engine.build().unwrap();
                cluster.run(|ctx| {
                    let world = Communicator::from_ctx(ctx);
                    run_rank(&world, &dims, &dims, &cfg, engine.as_ref()).0
                })
            };
            assert_eq!(
                run_mode(ExecutionMode::Blocking),
                run_mode(ExecutionMode::Async),
                "{pr}x{pc}: async must match blocking to the bit"
            );
        }
    }

    #[test]
    fn single_locality_degenerate() {
        let report = run(&Pencil3Config {
            grid: Grid3::new(8, 8, 8),
            proc: ProcGrid::new(1, 1),
            threads_per_locality: 1,
            ..Default::default()
        })
        .unwrap();
        assert!(report.rel_error.unwrap() < 1e-4);
        assert_eq!(report.stats.msgs_sent, 0, "1×1 moves nothing over the fabric");
    }

    #[test]
    fn tiny_wire_chunks_verify() {
        // Chunk size smaller than one extracted row: every transfer
        // splits into many mid-row windows on both rounds.
        let report = run(&Pencil3Config {
            chunk: ChunkPolicy::new(40, 2),
            ..acceptance_config(2, 2)
        })
        .unwrap();
        assert!(report.rel_error.unwrap() < 1e-4, "{:?}", report.rel_error);
    }

    #[test]
    fn indivisible_grid_rejected_with_error() {
        let err = run(&Pencil3Config {
            grid: Grid3::new(10, 8, 24),
            proc: ProcGrid::new(4, 1),
            ..Default::default()
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("not divisible"), "{err}");
    }

    #[test]
    fn async_reports_overlap_under_net_model() {
        let report = run(&Pencil3Config {
            grid: Grid3::new(32, 32, 32),
            exec: ExecutionMode::Async,
            chunk: ChunkPolicy::new(2048, 4),
            net: Some(NetModel::infiniband_hdr()),
            threads_per_locality: 1,
            ..Default::default()
        })
        .unwrap();
        assert!(report.rel_error.unwrap() < 1e-4);
        assert!(
            report.critical_path.overlap_us > 0.0,
            "async pencil run hid no wall time: {:?}",
            report.critical_path
        );
    }

    #[test]
    fn timings_populated_and_places_inside_comm() {
        let report = run(&acceptance_config(2, 2)).unwrap();
        for t in &report.per_rank {
            assert!(t.fft_z_us > 0.0 && t.fft_y_us > 0.0 && t.fft_x_us > 0.0);
            assert!(t.t1_comm_us >= t.t1_place_us, "{t:?}");
            assert!(t.t2_comm_us >= t.t2_place_us, "{t:?}");
            assert_eq!(t.overlap_us, 0.0, "blocking mode hides nothing");
        }
    }

    #[test]
    fn real_domain_verifies_all_shapes() {
        // 12×8×24 real input → 12×8×12 packed spectral grid; every
        // acceptance shape divides both.
        for (pr, pc) in [(1, 4), (2, 2), (4, 1)] {
            let report = run(&Pencil3Config {
                domain: Domain::Real,
                ..acceptance_config(pr, pc)
            })
            .unwrap();
            assert!(
                report.rel_error.unwrap() < 1e-4,
                "{pr}x{pc}: {:?}",
                report.rel_error
            );
            assert!(report.config_summary.contains("real domain"));
        }
    }

    #[test]
    fn real_domain_async_matches_blocking_bitwise() {
        for (pr, pc) in [(2, 2), (1, 4)] {
            let run_mode = |exec: ExecutionMode| {
                let cfg = Pencil3Config {
                    domain: Domain::Real,
                    exec,
                    chunk: ChunkPolicy::new(256, 2),
                    ..acceptance_config(pr, pc)
                };
                let cluster = Cluster::new(cfg.proc.n(), cfg.port, cfg.net).unwrap();
                run_on_collect(&cluster, &cfg).unwrap().1
            };
            assert_eq!(
                run_mode(ExecutionMode::Blocking),
                run_mode(ExecutionMode::Async),
                "{pr}x{pc}: real-domain async must match blocking to the bit"
            );
        }
    }

    #[test]
    fn real_domain_odd_z_extent_rejected() {
        let err = run(&Pencil3Config {
            grid: Grid3::new(8, 8, 9),
            proc: ProcGrid::new(2, 2),
            domain: Domain::Real,
            ..Default::default()
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("even z-extent"), "{err}");
    }

    #[test]
    fn real_domain_halves_wire_traffic() {
        let bytes = |domain: Domain| {
            run(&Pencil3Config {
                domain,
                verify: false,
                ..acceptance_config(2, 2)
            })
            .unwrap()
            .stats
            .bytes_sent
        };
        // The transpose payloads halve exactly; the (identical) split
        // bookkeeping traffic keeps the end-to-end ratio just above ½.
        let (complex, real) = (bytes(Domain::Complex), bytes(Domain::Real));
        assert!(
            (real as f64) <= 0.55 * complex as f64,
            "real {real} vs complex {complex}"
        );
    }

    #[test]
    fn zero_chunk_policy_rejected() {
        let err = run(&Pencil3Config {
            chunk: ChunkPolicy { chunk_bytes: 0, inflight: 2 },
            ..acceptance_config(2, 2)
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("chunk policy must be positive"), "{err}");
    }

    #[test]
    fn transposed_distribution_covers_reference_once() {
        let dims = PencilDims::new(Grid3::new(4, 4, 4), ProcGrid::new(2, 2)).unwrap();
        let reference: Vec<Complex32> =
            (0..64).map(|i| Complex32::new(i as f32, 0.0)).collect();
        let mut redistributed = distribute_transposed(&reference, &dims);
        redistributed.sort_by(|a, b| a.re.total_cmp(&b.re));
        let sorted: Vec<f32> = redistributed.iter().map(|c| c.re).collect();
        assert_eq!(sorted, (0..64).map(|i| i as f32).collect::<Vec<_>>());
    }
}
