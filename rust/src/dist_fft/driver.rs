//! End-to-end distributed FFT driver: configuration, compute-engine
//! abstraction, execution, verification, reporting.

use super::partition::{FftInput, RealSlab, Slab};
use super::verify::{rel_error, serial_fft2_transposed, serial_rfft2_packed_transposed};
use crate::collectives::{AllToAllAlgo, ChunkPolicy, Communicator};
use crate::fft::complex::Complex32;
use crate::fft::plan::{Direction, PlanCache};
use crate::hpx::runtime::Cluster;
use crate::parcelport::{NetModel, PortKind};
use std::sync::Arc;

/// Input domain of the distributed transform: the paper's complex (c2c)
/// benchmark, or the real-input (r2c) workload of its FFTW3+MPI
/// reference — whose first-axis FFT emits packed half-spectra of
/// `C/2` bins, so every transpose round moves half the wire bytes (the
/// CLI's `--domain` axis).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Domain {
    /// Complex-to-complex transform (the paper's benchmark).
    #[default]
    Complex,
    /// Real-to-complex transform: r2c first axis, packed half-spectrum
    /// transposes (~½ the wire traffic), complex second axis.
    Real,
}

impl Domain {
    /// Both domains, in presentation order.
    pub const ALL: [Domain; 2] = [Domain::Complex, Domain::Real];

    /// Lowercase domain name (CLI / CSV spelling).
    pub fn name(&self) -> &'static str {
        match self {
            Domain::Complex => "complex",
            Domain::Real => "real",
        }
    }
}

impl std::str::FromStr for Domain {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "complex" | "c2c" => Ok(Domain::Complex),
            "real" | "r2c" => Ok(Domain::Real),
            other => Err(format!("unknown domain {other:?} (expected complex|real)")),
        }
    }
}

/// Which communication variant to run (the paper's Fig. 4 vs Fig. 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// One synchronized all-to-all collective (Fig. 4).
    AllToAll,
    /// N scatter collectives with overlapped transposes (Fig. 5).
    Scatter,
}

impl Variant {
    /// Lowercase variant name (CLI / CSV spelling).
    pub fn name(&self) -> &'static str {
        match self {
            Variant::AllToAll => "all-to-all",
            Variant::Scatter => "scatter",
        }
    }
}

impl std::str::FromStr for Variant {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "all-to-all" | "all_to_all" | "a2a" => Ok(Variant::AllToAll),
            "scatter" | "n-scatter" => Ok(Variant::Scatter),
            other => Err(format!("unknown variant {other:?} (expected all-to-all|scatter)")),
        }
    }
}

/// How the distributed FFT drives its communication: lock-step blocking
/// collectives, or a future-chained task graph with comm/compute overlap
/// (the CLI's `--exec` axis, HPX's `hpx::collectives` future semantics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecutionMode {
    /// Phase-serialized execution over the blocking collective wrappers:
    /// compute and communication alternate in lock-step.
    #[default]
    Blocking,
    /// Future-chained task graph: wire chunks are posted the moment the
    /// rows feeding them finish their first-dimension FFT, arriving
    /// chunks are transpose-placed while later ones are in flight, and
    /// the second-dimension FFT runs as a continuation of "all my chunks
    /// arrived" while this rank's own sends are still draining. The
    /// hidden wall time is reported as `StepTimings::overlap_us`.
    Async,
}

impl ExecutionMode {
    /// Both modes, in presentation order.
    pub const ALL: [ExecutionMode; 2] = [ExecutionMode::Blocking, ExecutionMode::Async];

    /// Lowercase mode name (CLI / CSV spelling).
    pub fn name(&self) -> &'static str {
        match self {
            ExecutionMode::Blocking => "blocking",
            ExecutionMode::Async => "async",
        }
    }
}

impl std::str::FromStr for ExecutionMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "blocking" | "sync" => Ok(ExecutionMode::Blocking),
            "async" | "futures" => Ok(ExecutionMode::Async),
            other => Err(format!("unknown execution mode {other:?} (expected blocking|async)")),
        }
    }
}

/// Row-FFT compute engine: the per-locality step-1/step-4 kernel.
/// Implemented by the native plan cache and by the PJRT artifact service
/// ([`crate::runtime::PjrtRowFft`]).
pub trait RowFft: Sync {
    /// Forward-FFT every length-`row_len` row of `data` in place.
    fn fft_rows(&self, data: &mut [Complex32], row_len: usize, nthreads: usize);

    /// Engine label for reports.
    fn name(&self) -> &'static str;
}

/// Native mixed-radix engine (the FFTW stand-in): cached plans, row
/// batches fanned out over the shared worker pool.
pub struct NativeRowFft;

impl RowFft for NativeRowFft {
    fn fft_rows(&self, data: &mut [Complex32], row_len: usize, nthreads: usize) {
        let plan = PlanCache::global().plan(row_len, Direction::Forward);
        crate::fft::batch::fft_rows_parallel(data, row_len, &plan, nthreads);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Compute-engine selector (CLI level).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ComputeEngine {
    /// In-process radix-2 kernels.
    Native,
    /// AOT-compiled JAX/Pallas artifact executed via PJRT; the value is
    /// the artifacts directory.
    Pjrt(String),
}

impl ComputeEngine {
    /// Instantiate the selected engine.
    pub fn build(&self) -> anyhow::Result<Arc<dyn RowFft + Send>> {
        match self {
            ComputeEngine::Native => Ok(Arc::new(NativeRowFft)),
            ComputeEngine::Pjrt(dir) => {
                Ok(Arc::new(crate::runtime::PjrtRowFft::new(dir)?) as Arc<dyn RowFft + Send>)
            }
        }
    }
}

impl std::str::FromStr for ComputeEngine {
    type Err = String;
    /// `native`, or `pjrt:<artifact-dir>` (config-file / spec spelling).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s.eq_ignore_ascii_case("native") {
            return Ok(ComputeEngine::Native);
        }
        s.strip_prefix("pjrt:")
            .map(|dir| ComputeEngine::Pjrt(dir.to_string()))
            .ok_or_else(|| {
                format!("unknown engine {s:?} (expected native|pjrt:<artifact-dir>)")
            })
    }
}

/// Per-step wall-clock timings (µs) for one locality.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTimings {
    /// Step-1 row FFTs (length `C`).
    pub fft1_us: f64,
    /// Wall time of the communication phase. In the scatter variant this
    /// *includes* the overlapped transposes.
    pub comm_us: f64,
    /// Time spent placing chunks (subset of `comm_us` for the scatter
    /// variant; a separate serial step for all-to-all).
    pub transpose_us: f64,
    /// Step-4 row FFTs (length `R`).
    pub fft2_us: f64,
    /// Compute wall time that executed *while collective traffic was
    /// still in flight* — the comm/compute overlap window the async
    /// execution mode exists to widen (band FFTs issued after the first
    /// chunk was posted, on-arrival transposes, and the slice of the
    /// second-dimension FFT that ran before this rank's last outgoing
    /// chunk completed). Always 0 in blocking mode.
    pub overlap_us: f64,
    /// End-to-end wall time of the four steps.
    pub total_us: f64,
}

impl StepTimings {
    /// Element-wise max across localities — the critical path.
    pub fn max(timings: &[StepTimings]) -> StepTimings {
        let mut out = StepTimings::default();
        for t in timings {
            out.fft1_us = out.fft1_us.max(t.fft1_us);
            out.comm_us = out.comm_us.max(t.comm_us);
            out.transpose_us = out.transpose_us.max(t.transpose_us);
            out.fft2_us = out.fft2_us.max(t.fft2_us);
            out.overlap_us = out.overlap_us.max(t.overlap_us);
            out.total_us = out.total_us.max(t.total_us);
        }
        out
    }
}

/// Full configuration of one distributed FFT execution.
///
/// Grid sides may be any length (the planner factorizes them into
/// mixed-radix stages; e.g. a 12×96×1000-style slab sweep is fine) as
/// long as both divide evenly by `localities`.
#[derive(Clone, Debug)]
pub struct DistFftConfig {
    /// Global grid rows (any length, multiple of `localities`).
    pub rows: usize,
    /// Global grid columns (any length, multiple of `localities`).
    pub cols: usize,
    /// Number of participating localities.
    pub localities: usize,
    /// Parcelport backend.
    pub port: PortKind,
    /// Communication variant (Fig. 4 vs Fig. 5).
    pub variant: Variant,
    /// All-to-all algorithm (ignored by the scatter variant).
    pub algo: AllToAllAlgo,
    /// Wire-chunking policy installed on every locality's communicator —
    /// governs the chunked/pipelined collectives and the chunk-grain
    /// comm/transpose overlap.
    pub chunk: ChunkPolicy,
    /// Lock-step blocking collectives vs the future-chained task graph
    /// (the `--exec` benchmark axis).
    pub exec: ExecutionMode,
    /// Input domain: complex (c2c) or real (r2c with packed
    /// half-spectrum transposes — the `--domain` axis). Real grids need
    /// an even `cols` with `cols/2` divisible by `localities`, and the
    /// native compute engine.
    pub domain: Domain,
    /// Worker threads per locality for the row-FFT steps.
    pub threads_per_locality: usize,
    /// Optional hybrid wire model.
    pub net: Option<NetModel>,
    /// Row-FFT compute engine.
    pub engine: ComputeEngine,
    /// Compare the distributed result against the serial reference.
    pub verify: bool,
}

impl Default for DistFftConfig {
    fn default() -> Self {
        Self {
            rows: 256,
            cols: 256,
            localities: 4,
            port: PortKind::Lci,
            variant: Variant::Scatter,
            algo: AllToAllAlgo::HpxRoot,
            chunk: ChunkPolicy::default(),
            exec: ExecutionMode::Blocking,
            domain: Domain::Complex,
            threads_per_locality: 2,
            net: None,
            engine: ComputeEngine::Native,
            verify: true,
        }
    }
}

impl DistFftConfig {
    /// The execution settings this config shares with every other
    /// transform shape, as a [`crate::config::TransformSpec`].
    pub fn spec(&self) -> crate::config::TransformSpec {
        crate::config::TransformSpec {
            port: self.port,
            chunk: self.chunk,
            exec: self.exec,
            domain: self.domain,
            threads_per_locality: self.threads_per_locality,
            net: self.net,
            engine: self.engine.clone(),
            verify: self.verify,
        }
    }

    /// Overwrite the shared execution settings from a
    /// [`crate::config::TransformSpec`], leaving the 2-D shape fields
    /// (`rows`/`cols`/`localities`/`variant`/`algo`) untouched.
    pub fn apply_spec(&mut self, spec: &crate::config::TransformSpec) {
        self.port = spec.port;
        self.chunk = spec.chunk;
        self.exec = spec.exec;
        self.domain = spec.domain;
        self.threads_per_locality = spec.threads_per_locality;
        self.net = spec.net;
        self.engine = spec.engine.clone();
        self.verify = spec.verify;
    }
}

/// Execution report.
#[derive(Clone, Debug)]
pub struct DistFftReport {
    /// One-line description of the executed configuration.
    pub config_summary: String,
    /// Per-locality step timings, rank order.
    pub per_rank: Vec<StepTimings>,
    /// Element-wise max across localities.
    pub critical_path: StepTimings,
    /// Relative L2 error vs. the serial reference (if verified).
    pub rel_error: Option<f64>,
    /// Traffic accounted by the parcelport during the run.
    pub stats: crate::parcelport::PortStatsSnapshot,
}

/// Run one distributed FFT end to end on a fresh cluster.
#[deprecated(note = "build a `dist_fft::TransformRequest` and call `Transform::run` instead")]
pub fn run(config: &DistFftConfig) -> anyhow::Result<DistFftReport> {
    let cluster = Cluster::new(config.localities, config.port, config.net)?;
    run_on_impl(&cluster, config).map(|(report, _)| report)
}

/// Run on an existing cluster (benchmarks reuse fabrics across reps).
#[deprecated(
    note = "build a `dist_fft::TransformRequest` and call `Transform::run_on` instead"
)]
pub fn run_on(cluster: &Cluster, config: &DistFftConfig) -> anyhow::Result<DistFftReport> {
    run_on_impl(cluster, config).map(|(report, _)| report)
}

/// Validate everything about a configuration that does not require a
/// live cluster — grid shape, domain preconditions, chunk policy. Both
/// the deprecated driver shims and [`TransformRequest::build`] route
/// through here, so the actionable error strings are identical on every
/// entry path.
///
/// [`TransformRequest::build`]: super::TransformRequest::build
pub(crate) fn validate_config(config: &DistFftConfig) -> anyhow::Result<()> {
    anyhow::ensure!(config.rows >= 1 && config.cols >= 1, "grid must be non-empty");
    // Real-domain preconditions come first: the generic divisibility
    // check below would otherwise shadow the r2c-specific messages
    // (an odd `cols` usually fails both).
    if config.domain == Domain::Real {
        anyhow::ensure!(
            config.cols % 2 == 0,
            "real-domain grids need an even column count (r2c packs the \
             half-spectrum into cols/2 bins), got cols = {}",
            config.cols
        );
        anyhow::ensure!(
            (config.cols / 2) % config.localities == 0,
            "real-domain grid {}×{}: the packed spectrum has {} columns, \
             which must divide evenly across {} localities (cols must be \
             a multiple of 2·N)",
            config.rows,
            config.cols,
            config.cols / 2,
            config.localities
        );
        anyhow::ensure!(
            matches!(config.engine, ComputeEngine::Native),
            "real-domain runs require the native compute engine \
             (--engine native); the PJRT artifact only compiles c2c rows"
        );
    }
    // Any row/column length is supported — the planner is mixed-radix —
    // but the slab decomposition needs uniform slabs and chunks.
    anyhow::ensure!(
        config.rows % config.localities == 0 && config.cols % config.localities == 0,
        "grid {}×{} must divide evenly across {} localities \
         (rows and cols may be any length, e.g. 12×96, but both must be \
         multiples of the locality count)",
        config.rows,
        config.cols,
        config.localities
    );
    // Hand-built zero policies would otherwise be clamped silently deep
    // inside the chunked wire protocol — reject them before anything
    // runs (the CLI and config file reject them at parse time already).
    config.chunk.validate()?;
    Ok(())
}

/// Execute the full transform on a cluster, returning the report plus
/// each rank's spectral piece (rank order) — the engine behind both the
/// deprecated [`run_on`] shim and [`Transform::run_on`].
///
/// [`Transform::run_on`]: super::Transform::run_on
pub(crate) fn run_on_impl(
    cluster: &Cluster,
    config: &DistFftConfig,
) -> anyhow::Result<(DistFftReport, Vec<Vec<Complex32>>)> {
    validate_config(config)?;
    anyhow::ensure!(
        cluster.n_localities() == config.localities,
        "cluster size mismatch: {} vs {}",
        cluster.n_localities(),
        config.localities
    );
    let engine = config.engine.build()?;
    let before = cluster.fabric().stats();

    let results: Vec<(Vec<Complex32>, StepTimings)> = cluster.run(|ctx| {
        let comm = Communicator::from_ctx(ctx);
        run_rank(&comm, config, engine.as_ref())
    });

    let stats = cluster.fabric().stats().since(&before);
    let per_rank: Vec<StepTimings> = results.iter().map(|(_, t)| *t).collect();
    let critical_path = StepTimings::max(&per_rank);
    let pieces: Vec<Vec<Complex32>> = results.into_iter().map(|(p, _)| p).collect();

    let rel_err = if config.verify { Some(verify_pieces(config, &pieces)) } else { None };

    let report = DistFftReport {
        config_summary: summary_line(config, engine.name()),
        per_rank,
        critical_path,
        rel_error: rel_err,
        stats,
    };
    Ok((report, pieces))
}

/// One rank's share of the transform, over an arbitrary communicator of
/// `config.localities` ranks. The cluster driver hands it the world
/// communicator; [`crate::runtime::FftService`] hands it a per-job
/// sub-communicator, which is how many transforms run concurrently on
/// one fabric.
pub(crate) fn run_rank(
    comm: &Communicator,
    config: &DistFftConfig,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    debug_assert_eq!(
        comm.size(),
        config.localities,
        "communicator size must match the configured locality count"
    );
    comm.set_chunk_policy(config.chunk);
    // The send pool is a communicator-lifetime resource; spawn it
    // before the timed region (blocking wrappers route through it
    // too, now that the collective engine is futures-first).
    comm.warm_chunk_pool();
    let rank = comm.rank();
    match config.domain {
        Domain::Complex => {
            let slab = Slab::synthetic(config.rows, config.cols, config.localities, rank);
            run_variant(comm, &FftInput::Complex(&slab), config, engine)
        }
        Domain::Real => {
            let slab = RealSlab::synthetic(config.rows, config.cols, config.localities, rank);
            run_variant(comm, &FftInput::Real(&slab), config, engine)
        }
    }
}

/// Relative L2 error of assembled per-rank pieces vs. the serial
/// reference for this configuration's synthetic input.
pub(crate) fn verify_pieces(config: &DistFftConfig, pieces: &[Vec<Complex32>]) -> f64 {
    let spectral_elems = match config.domain {
        Domain::Complex => config.rows * config.cols,
        Domain::Real => config.rows * config.cols / 2,
    };
    let mut assembled = Vec::with_capacity(spectral_elems);
    for piece in pieces {
        assembled.extend_from_slice(piece);
    }
    let reference = match config.domain {
        Domain::Complex => serial_fft2_transposed(
            &Slab::whole(config.rows, config.cols).data,
            config.rows,
            config.cols,
        ),
        Domain::Real => serial_rfft2_packed_transposed(
            &RealSlab::whole(config.rows, config.cols).data,
            config.rows,
            config.cols,
        ),
    };
    rel_error(&assembled, &reference)
}

/// One-line human description of an executed configuration.
pub(crate) fn summary_line(config: &DistFftConfig, engine_name: &str) -> String {
    format!(
        "{}×{} grid, {} localities, {} port, {} variant, {} exec, {} domain, {} engine",
        config.rows,
        config.cols,
        config.localities,
        config.port,
        config.variant.name(),
        config.exec.name(),
        config.domain.name(),
        engine_name,
    )
}

/// Dispatch one locality's run to the configured variant × execution
/// mode over the given input domain.
fn run_variant(
    comm: &Communicator,
    input: &FftInput<'_>,
    config: &DistFftConfig,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    let nthreads = config.threads_per_locality;
    match (config.variant, config.exec) {
        (Variant::AllToAll, ExecutionMode::Blocking) => {
            super::all_to_all_variant::run_input_impl(comm, input, config.algo, nthreads, engine)
        }
        (Variant::AllToAll, ExecutionMode::Async) => {
            super::all_to_all_variant::run_async_input_impl(
                comm, input, config.algo, nthreads, engine,
            )
        }
        (Variant::Scatter, ExecutionMode::Blocking) => {
            super::scatter_variant::run_input_impl(comm, input, nthreads, engine)
        }
        (Variant::Scatter, ExecutionMode::Async) => {
            super::scatter_variant::run_async_input_impl(comm, input, nthreads, engine)
        }
    }
}

#[cfg(test)]
// The module exercises the deprecated `run`/`run_on` shims on purpose:
// they must keep working until every external caller has migrated to
// `TransformRequest`.
#[allow(deprecated)]
mod tests {
    use super::*;

    #[test]
    fn default_config_runs_and_verifies() {
        let config = DistFftConfig { rows: 32, cols: 32, ..Default::default() };
        let report = run(&config).unwrap();
        assert!(report.rel_error.unwrap() < 1e-4);
        assert_eq!(report.per_rank.len(), 4);
        assert!(report.critical_path.total_us > 0.0);
        assert!(report.stats.msgs_sent > 0);
    }

    #[test]
    fn all_variants_and_ports_verify() {
        for port in PortKind::ALL {
            for variant in [Variant::AllToAll, Variant::Scatter] {
                let config = DistFftConfig {
                    rows: 16,
                    cols: 16,
                    localities: 2,
                    port,
                    variant,
                    ..Default::default()
                };
                let report = run(&config).unwrap();
                assert!(
                    report.rel_error.unwrap() < 1e-4,
                    "{port} {variant:?}: {:?}",
                    report.rel_error
                );
            }
        }
    }

    #[test]
    fn pairwise_chunked_variant_verifies_with_tiny_chunks() {
        // Forces many wire chunks per message (policy aligned down to 96
        // bytes by the variant) on every port.
        for port in PortKind::ALL {
            let config = DistFftConfig {
                rows: 32,
                cols: 32,
                localities: 4,
                port,
                variant: Variant::AllToAll,
                algo: AllToAllAlgo::PairwiseChunked,
                chunk: ChunkPolicy::new(100, 2),
                threads_per_locality: 1,
                ..Default::default()
            };
            let report = run(&config).unwrap();
            assert!(report.rel_error.unwrap() < 1e-4, "{port}: {:?}", report.rel_error);
        }
    }

    #[test]
    fn non_pow2_grid_verifies() {
        // 12×20 on 4 localities: 3 rows and 5 columns per slab — both
        // mixed-radix lengths, both variants.
        for variant in [Variant::AllToAll, Variant::Scatter] {
            let config =
                DistFftConfig { rows: 12, cols: 20, variant, ..Default::default() };
            let report = run(&config).unwrap();
            assert!(
                report.rel_error.unwrap() < 1e-4,
                "{variant:?}: {:?}",
                report.rel_error
            );
        }
    }

    #[test]
    fn indivisible_grid_rejected() {
        // 30 rows cannot slab evenly over 4 localities.
        let config = DistFftConfig { rows: 30, cols: 32, ..Default::default() };
        let err = run(&config).unwrap_err().to_string();
        assert!(err.contains("divide evenly"), "{err}");
    }

    #[test]
    fn real_domain_verifies_both_variants_and_modes() {
        for variant in [Variant::AllToAll, Variant::Scatter] {
            for exec in ExecutionMode::ALL {
                let config = DistFftConfig {
                    rows: 16,
                    cols: 32,
                    domain: Domain::Real,
                    variant,
                    exec,
                    threads_per_locality: 1,
                    ..Default::default()
                };
                let report = run(&config).unwrap();
                assert!(
                    report.rel_error.unwrap() < 1e-4,
                    "{variant:?} {exec:?}: {:?}",
                    report.rel_error
                );
                assert!(report.config_summary.contains("real domain"));
            }
        }
    }

    #[test]
    fn real_domain_non_pow2_grid_verifies() {
        // 12×24 on 4 localities: packed spectrum 12 columns, 3 per rank.
        let config = DistFftConfig {
            rows: 12,
            cols: 24,
            domain: Domain::Real,
            threads_per_locality: 1,
            ..Default::default()
        };
        let report = run(&config).unwrap();
        assert!(report.rel_error.unwrap() < 1e-4, "{:?}", report.rel_error);
    }

    #[test]
    fn real_domain_odd_cols_rejected() {
        let config =
            DistFftConfig { rows: 16, cols: 27, domain: Domain::Real, ..Default::default() };
        let err = run(&config).unwrap_err().to_string();
        assert!(err.contains("even column count"), "{err}");
    }

    #[test]
    fn real_domain_indivisible_packed_cols_rejected() {
        // cols = 24 divides by 4 localities but cols/2 = 12 does not
        // divide by 8.
        let config = DistFftConfig {
            rows: 16,
            cols: 24,
            localities: 8,
            domain: Domain::Real,
            ..Default::default()
        };
        let err = run(&config).unwrap_err().to_string();
        assert!(err.contains("packed spectrum"), "{err}");
    }

    #[test]
    fn hand_built_zero_chunk_policy_rejected_with_actionable_error() {
        // `ChunkPolicy::new` panics on zero, but the fields are public —
        // a hand-built zero policy must be rejected up front instead of
        // being clamped silently inside the wire protocol.
        for chunk in [
            ChunkPolicy { chunk_bytes: 0, inflight: 4 },
            ChunkPolicy { chunk_bytes: 1024, inflight: 0 },
        ] {
            let config = DistFftConfig { rows: 16, cols: 16, chunk, ..Default::default() };
            let err = run(&config).unwrap_err().to_string();
            assert!(err.contains("chunk policy must be positive"), "{err}");
            assert!(err.contains("--chunk-bytes"), "{err}");
        }
    }

    #[test]
    fn domain_parse() {
        assert_eq!("real".parse::<Domain>().unwrap(), Domain::Real);
        assert_eq!("r2c".parse::<Domain>().unwrap(), Domain::Real);
        assert_eq!("complex".parse::<Domain>().unwrap(), Domain::Complex);
        assert_eq!("c2c".parse::<Domain>().unwrap(), Domain::Complex);
        assert!("quaternion".parse::<Domain>().is_err());
        assert_eq!(Domain::default(), Domain::Complex);
        assert_eq!(Domain::ALL.len(), 2);
    }

    #[test]
    fn variant_parse() {
        assert_eq!("scatter".parse::<Variant>().unwrap(), Variant::Scatter);
        assert_eq!("a2a".parse::<Variant>().unwrap(), Variant::AllToAll);
        assert!("ring".parse::<Variant>().is_err());
    }

    #[test]
    fn exec_mode_parse() {
        assert_eq!("blocking".parse::<ExecutionMode>().unwrap(), ExecutionMode::Blocking);
        assert_eq!("async".parse::<ExecutionMode>().unwrap(), ExecutionMode::Async);
        assert_eq!("futures".parse::<ExecutionMode>().unwrap(), ExecutionMode::Async);
        assert!("eager".parse::<ExecutionMode>().is_err());
        assert_eq!(ExecutionMode::default(), ExecutionMode::Blocking);
    }

    #[test]
    fn async_exec_verifies_both_variants() {
        for variant in [Variant::AllToAll, Variant::Scatter] {
            let config = DistFftConfig {
                rows: 16,
                cols: 32,
                localities: 4,
                variant,
                exec: ExecutionMode::Async,
                ..Default::default()
            };
            let report = run(&config).unwrap();
            assert!(
                report.rel_error.unwrap() < 1e-4,
                "{variant:?} async: {:?}",
                report.rel_error
            );
            assert!(report.config_summary.contains("async"));
        }
    }

    #[test]
    fn async_exec_reports_overlap_with_net_model() {
        // Under the wire model the async schedule must actually hide
        // some wall time (the full bitwise blocking-vs-async equivalence
        // matrix lives in tests/integration.rs).
        let config = DistFftConfig {
            rows: 64,
            cols: 64,
            localities: 4,
            exec: ExecutionMode::Async,
            chunk: ChunkPolicy::new(4096, 4),
            net: Some(crate::parcelport::NetModel::infiniband_hdr()),
            threads_per_locality: 1,
            ..Default::default()
        };
        let report = run(&config).unwrap();
        assert!(report.rel_error.unwrap() < 1e-4);
        assert!(
            report.critical_path.overlap_us > 0.0,
            "async run hid no wall time: {:?}",
            report.critical_path
        );
    }

    #[test]
    fn multithreaded_localities_match() {
        let base = DistFftConfig {
            rows: 64,
            cols: 64,
            localities: 2,
            threads_per_locality: 1,
            ..Default::default()
        };
        let a = run(&base).unwrap();
        let b = run(&DistFftConfig { threads_per_locality: 4, ..base }).unwrap();
        assert!(a.rel_error.unwrap() < 1e-4);
        assert!(b.rel_error.unwrap() < 1e-4);
    }
}
