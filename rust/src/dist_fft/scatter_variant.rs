//! Variant B — *N-scatter* with transpose/communication overlap
//! (paper Fig. 5, the paper's proposed improvement).
//!
//! The all-to-all is replaced by N scatter collectives, one rooted at
//! each locality. Because each scatter completes independently, a
//! receiver transposes every chunk *the moment it arrives* instead of
//! waiting for the full exchange — "the arriving data chunks can be
//! transposed as soon as they are received" (§3). The receive loop polls
//! all outstanding roots and interleaves placement work with waiting,
//! which is where the overlap (and the win over Fig. 4) comes from.
//!
//! The overlap granularity is the communicator's
//! [`crate::collectives::ChunkPolicy`]: per-root payloads ship as
//! pipelined zero-copy wire chunks ([`Payload::slice`] views drained by
//! the chunk send pool), and the poll loop places each *wire chunk* as
//! it lands — chunk *k* is unpacked while chunk *k+1* is still on the
//! wire, even within a single root's message.

use super::driver::{RowFft, StepTimings};
use super::partition::{FftInput, Slab};
use super::transpose::{place_chunk_slice_transposed, place_chunk_transposed};
use crate::collectives::Communicator;
use crate::fft::complex::{from_le_bytes, Complex32};
use crate::hpx::parcel::Payload;
use crate::task::TaskFuture;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Run the four-step distributed FFT with N overlapped scatters
/// (complex domain — see [`run_input`] for the domain-polymorphic
/// entry point).
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `Variant::Scatter` instead of \
            calling the variant entry point directly"
)]
pub fn run(
    comm: &Communicator,
    slab: &Slab,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    run_input_impl(comm, &FftInput::Complex(slab), nthreads, engine)
}

/// [`run`] over either input domain.
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `Variant::Scatter` instead of \
            calling the variant entry point directly"
)]
pub fn run_input(
    comm: &Communicator,
    input: &FftInput<'_>,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    run_input_impl(comm, input, nthreads, engine)
}

/// Run the four-step distributed FFT with N overlapped scatters over
/// either input domain. Stage 1 transforms the local rows (c2c, or r2c
/// into packed half-spectra — [`FftInput::stage1_band`]); everything
/// after sees a spectral slab of [`FftInput::spectral_cols`] columns,
/// so a real-domain run ships half the complex-domain payload over the
/// same wire protocol.
pub(crate) fn run_input_impl(
    comm: &Communicator,
    input: &FftInput<'_>,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    let n = comm.size();
    let me = comm.rank();
    debug_assert_eq!(input.parts(), n, "input decomposition must match the communicator");
    let lr = input.local_rows();
    let cw = Slab::cols_per_chunk(input.spectral_cols(), n);
    let r_total = input.global_rows();
    let mut timings = StepTimings::default();
    let t_start = Instant::now();

    // Step 1: first-axis row transforms (length C; packed C/2-bin
    // spectra in the real domain).
    let t0 = Instant::now();
    let mut work = input.stage1_seed();
    {
        let _span = crate::obs::span("fft", "stage1", comm.my_global());
        input.stage1_band(&mut work, 0, lr, engine, nthreads);
    }
    timings.fft1_us = t0.elapsed().as_secs_f64() * 1e6;

    // Steps 2+3 fused: N chunk-pipelined scatters; transpose each wire
    // chunk on arrival.
    const ELEM: usize = std::mem::size_of::<Complex32>();
    comm.set_chunk_policy(comm.chunk_policy().aligned(ELEM));
    let policy = comm.chunk_policy();
    let t0 = Instant::now();
    let mut transpose_spent = 0.0f64;
    let tags = comm.scatter_chunk_tags(n);
    let tmp = Slab {
        global_rows: r_total,
        global_cols: input.spectral_cols(),
        parts: n,
        rank: me,
        data: work,
    }; // The *spectral* slab: chunk extraction and wire sizing run on the
       // stage-1 output geometry, which is what makes the real domain's
       // halved payload fall out of the unchanged protocol below.
    let mut next = vec![Complex32::ZERO; cw * r_total];

    // Every rank derives the transfer size from the slab geometry, so
    // the wire carries no length headers — just the chunks themselves
    // (the known-size chunked protocol).
    let chunk_bytes_total = lr * cw * ELEM;
    let wire_chunks = policy.n_chunks(chunk_bytes_total);

    // Post my own scatter (root = me): ship chunk j to locality j as
    // pipelined wire chunks on the send pool.
    let mut own_chunk: Option<Vec<Complex32>> = None;
    let mut sends_pending = Vec::new();
    for dst in 0..n {
        if dst == me {
            own_chunk = Some(tmp.extract_chunk(dst));
        } else {
            // Single-pass wire serialization (§Perf).
            sends_pending.append(&mut comm.send_chunked_sized(
                dst,
                tags[me],
                Payload::new(tmp.extract_chunk_bytes(dst)),
            ));
        }
    }

    // My own chunk is "received" immediately — transpose it first (free
    // overlap while peers' chunks are in flight).
    {
        let tt = Instant::now();
        let chunk = own_chunk.expect("own chunk extracted");
        let _span = crate::obs::span("place", "own", comm.my_global());
        place_chunk_transposed(&chunk, lr, cw, &mut next, r_total, me * lr);
        transpose_spent += tt.elapsed().as_secs_f64() * 1e6;
    }

    // Poll the remaining roots; place whichever *wire chunk* lands
    // first, consuming each root's chunks in offset order.
    let mut pending: Vec<(usize, usize)> = // (root, next wire-chunk index)
        (0..n).filter(|&r| r != me).map(|root| (root, 0)).collect();
    while !pending.is_empty() {
        let mut progressed = false;
        let mut i = 0;
        while i < pending.len() {
            let (root, next_chunk) = &mut pending[i];
            while *next_chunk < wire_chunks {
                let Some(payload) = comm.try_recv_chunk(*root, tags[*root], *next_chunk)
                else {
                    break;
                };
                let tt = Instant::now();
                let elems = from_le_bytes(payload.as_bytes());
                let span = crate::obs::span_args(
                    "place",
                    "chunk",
                    comm.my_global(),
                    tags[*root] as i64,
                    *next_chunk as i64,
                    payload.len() as i64,
                );
                place_chunk_slice_transposed(
                    &elems,
                    *next_chunk * policy.chunk_bytes / ELEM,
                    lr,
                    cw,
                    &mut next,
                    r_total,
                    *root * lr,
                );
                drop(span);
                transpose_spent += tt.elapsed().as_secs_f64() * 1e6;
                *next_chunk += 1;
                progressed = true;
            }
            if *next_chunk >= wire_chunks {
                pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if !progressed {
            std::hint::spin_loop();
            std::thread::yield_now();
        }
    }
    for f in sends_pending {
        f.get();
    }
    timings.comm_us = t0.elapsed().as_secs_f64() * 1e6;
    timings.transpose_us = transpose_spent; // informational: overlapped inside comm_us

    // Step 4: row FFTs of the transposed slab (length R).
    let t0 = Instant::now();
    {
        let _span = crate::obs::span("fft", "stage2", comm.my_global());
        engine.fft_rows(&mut next, r_total, nthreads);
    }
    timings.fft2_us = t0.elapsed().as_secs_f64() * 1e6;

    timings.total_us = t_start.elapsed().as_secs_f64() * 1e6;
    (next, timings)
}

/// Compute time of segment `[start, end)` that executed before `until` —
/// the slice of a compute phase hidden inside the comm window, µs.
pub(crate) fn hidden_us(start: Instant, end: Instant, until: Instant) -> f64 {
    if until <= start {
        return 0.0;
    }
    let covered = if until < end { until } else { end };
    covered.duration_since(start).as_secs_f64() * 1e6
}

/// Run the four-step distributed FFT as a future-chained task graph
/// (`--exec async`): identical arithmetic to [`run`], maximal overlap.
///
/// The schedule, per rank:
///
/// 1. the first-dimension row FFT executes in *wire-chunk bands*; the
///    moment band *b*'s rows are transformed, band *b* is posted to every
///    peer as wire chunk *b* of this rank's scatter (futures from the
///    send pool) — so peers start receiving while later bands are still
///    being transformed;
/// 2. arriving wire chunks are transpose-placed in arrival order while
///    later chunks (and this rank's own sends) are still in flight;
/// 3. the second-dimension row FFT of this rank's slab runs as the
///    continuation of "all my chunks arrived" — *without* waiting for
///    this rank's outgoing chunks, which keep draining underneath it and
///    are settled only at the very end.
///
/// The wall time hidden this way (band FFTs after the first post,
/// on-arrival transposes, and the slice of the second FFT that ran before
/// the last outgoing chunk completed) is reported as
/// [`StepTimings::overlap_us`].
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `Variant::Scatter` and \
            `ExecutionMode::Async` instead of calling the variant entry point directly"
)]
pub fn run_async(
    comm: &Communicator,
    slab: &Slab,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    run_async_input_impl(comm, &FftInput::Complex(slab), nthreads, engine)
}

/// [`run_async`] over either input domain.
#[deprecated(
    note = "build a `dist_fft::TransformRequest` with `Variant::Scatter` and \
            `ExecutionMode::Async` instead of calling the variant entry point directly"
)]
pub fn run_async_input(
    comm: &Communicator,
    input: &FftInput<'_>,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    run_async_input_impl(comm, input, nthreads, engine)
}

/// [`run`] in async form over either input domain — the banded stage-1
/// loop calls [`FftInput::stage1_band`], so in the real domain each wire
/// band is r2c-transformed into packed half-spectra the moment before
/// it is posted (half the bytes per band, same schedule).
pub(crate) fn run_async_input_impl(
    comm: &Communicator,
    input: &FftInput<'_>,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    let n = comm.size();
    let me = comm.rank();
    debug_assert_eq!(input.parts(), n, "input decomposition must match the communicator");
    let lr = input.local_rows();
    let cw = Slab::cols_per_chunk(input.spectral_cols(), n);
    let r_total = input.global_rows();
    let c_total = input.spectral_cols();
    let mut timings = StepTimings::default();
    let t_start = Instant::now();

    const ELEM: usize = std::mem::size_of::<Complex32>();
    // Row-aligned wire chunks: each wire chunk covers whole chunk rows,
    // so a band of freshly transformed local rows maps exactly onto one
    // wire chunk per destination. The geometry is derived locally from
    // the installed policy — which every rank shares under the SPMD
    // discipline — and the policy itself is left untouched (the async
    // wire protocol carries no headers, so nothing else reads it here).
    let row_bytes = cw * ELEM;
    let base_policy = comm.chunk_policy();
    let rows_per_wire = (base_policy.chunk_bytes / row_bytes).clamp(1, lr);
    let wire_chunks = lr.div_ceil(rows_per_wire);
    let tags = comm.scatter_chunk_tags(n);

    let mut work = input.stage1_seed();
    let mut next = vec![Complex32::ZERO; cw * r_total];
    let mut sends_pending: Vec<TaskFuture<()>> = Vec::new();
    // Completion timestamp of the most recent outgoing chunk, recorded by
    // a continuation on whichever pool worker finishes it.
    let last_send_done: Arc<Mutex<Option<Instant>>> = Arc::new(Mutex::new(None));

    let mut fft1_spent = 0.0f64;
    let mut transpose_spent = 0.0f64;
    let mut overlapped = 0.0f64;
    let mut comm_open: Option<Instant> = None; // first chunk posted

    // Step 1, banded + streamed: FFT a band, post it, transpose own part.
    for wc in 0..wire_chunks {
        let r0 = wc * rows_per_wire;
        let r1 = (r0 + rows_per_wire).min(lr);
        let tb = Instant::now();
        {
            // Band spans overlap the "wire" chunk spans of earlier bands
            // on the exported timeline — overlap_us, made visible.
            let _span = crate::obs::span_args(
                "fft",
                "band",
                comm.my_global(),
                crate::obs::NO_ARG,
                wc as i64,
                crate::obs::NO_ARG,
            );
            input.stage1_band(&mut work, r0, r1, engine, nthreads);
        }
        let band_us = tb.elapsed().as_secs_f64() * 1e6;
        fft1_spent += band_us;
        if comm_open.is_some() {
            overlapped += band_us; // transformed while earlier bands flew
        }

        for dst in 0..n {
            if dst == me {
                continue;
            }
            let payload = Payload::new(Slab::extract_chunk_rows_bytes(
                &work, c_total, n, dst, r0, r1,
            ));
            let send = comm.send_wire_chunk(dst, tags[me], wc, payload);
            let stamp = Arc::clone(&last_send_done);
            send.then_inline(move |_| {
                *stamp.lock().unwrap() = Some(Instant::now());
            });
            sends_pending.push(send);
        }
        if comm_open.is_none() && n > 1 {
            comm_open = Some(Instant::now());
        }

        // Own chunk band is "received" immediately — place it now (free
        // overlap while this band's wire chunks are in flight).
        let tt = Instant::now();
        let span = crate::obs::span_args(
            "place",
            "own",
            comm.my_global(),
            crate::obs::NO_ARG,
            wc as i64,
            crate::obs::NO_ARG,
        );
        let mut own = Vec::with_capacity((r1 - r0) * cw);
        for r in r0..r1 {
            let base = r * c_total + me * cw;
            own.extend_from_slice(&work[base..base + cw]);
        }
        place_chunk_slice_transposed(&own, r0 * cw, lr, cw, &mut next, r_total, me * lr);
        drop(span);
        let place_us = tt.elapsed().as_secs_f64() * 1e6;
        transpose_spent += place_us;
        if comm_open.is_some() {
            overlapped += place_us;
        }
    }
    timings.fft1_us = fft1_spent;

    // Steps 2+3: place whichever peer wire chunk lands first, in offset
    // order per root, while the rest are still on the wire.
    let mut pending: Vec<(usize, usize)> = // (root, next wire-chunk index)
        (0..n).filter(|&r| r != me).map(|root| (root, 0)).collect();
    while !pending.is_empty() {
        let mut progressed = false;
        let mut i = 0;
        while i < pending.len() {
            let (root, next_chunk) = &mut pending[i];
            while *next_chunk < wire_chunks {
                let Some(payload) = comm.try_recv_chunk(*root, tags[*root], *next_chunk)
                else {
                    break;
                };
                let tt = Instant::now();
                let elems = from_le_bytes(payload.as_bytes());
                let span = crate::obs::span_args(
                    "place",
                    "chunk",
                    comm.my_global(),
                    tags[*root] as i64,
                    *next_chunk as i64,
                    payload.len() as i64,
                );
                place_chunk_slice_transposed(
                    &elems,
                    *next_chunk * rows_per_wire * cw,
                    lr,
                    cw,
                    &mut next,
                    r_total,
                    *root * lr,
                );
                drop(span);
                let place_us = tt.elapsed().as_secs_f64() * 1e6;
                transpose_spent += place_us;
                overlapped += place_us;
                *next_chunk += 1;
                progressed = true;
            }
            if *next_chunk >= wire_chunks {
                pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if !progressed {
            std::hint::spin_loop();
            std::thread::yield_now();
        }
    }
    let t_recv_done = Instant::now();

    // Step 4 as the continuation of "all my chunks arrived": this rank's
    // outgoing chunks keep draining through the send pool underneath.
    let t_fft2 = Instant::now();
    {
        let _span = crate::obs::span("fft", "stage2", comm.my_global());
        engine.fft_rows(&mut next, r_total, nthreads);
    }
    let t_fft2_end = Instant::now();
    timings.fft2_us = t_fft2_end.duration_since(t_fft2).as_secs_f64() * 1e6;

    // Settle the sends (their completion instants were stamped by the
    // continuations above as they finished).
    for f in sends_pending {
        f.get();
    }
    if let Some(open) = comm_open {
        let sends_done = last_send_done.lock().unwrap().take().unwrap_or(t_recv_done);
        let comm_close = t_recv_done.max(sends_done);
        timings.comm_us = comm_close.duration_since(open).as_secs_f64() * 1e6;
        overlapped += hidden_us(t_fft2, t_fft2_end, sends_done);
        timings.overlap_us = overlapped;
    }
    timings.transpose_us = transpose_spent; // informational: overlapped
    timings.total_us = t_start.elapsed().as_secs_f64() * 1e6;
    (next, timings)
}

#[cfg(test)]
// Exercises the deprecated variant shims on purpose — shim coverage
// until every external caller has migrated to `TransformRequest`.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::dist_fft::driver::NativeRowFft;
    use crate::dist_fft::verify::{rel_error, serial_fft2_transposed};
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    fn check_variant(rows: usize, cols: usize, parts: usize, kind: PortKind) {
        let cluster = Cluster::new(parts, kind, None).unwrap();
        let pieces = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
            let (out, _t) = run(&comm, &slab, 1, &NativeRowFft);
            out
        });
        let mut assembled = Vec::with_capacity(rows * cols);
        for p in pieces {
            assembled.extend(p);
        }
        let reference = serial_fft2_transposed(&Slab::whole(rows, cols).data, rows, cols);
        let err = rel_error(&assembled, &reference);
        assert!(err < 1e-4, "rel err {err} ({kind} {parts} parts)");
    }

    #[test]
    fn matches_serial_all_ports() {
        check_variant(16, 32, 4, PortKind::Lci);
        check_variant(16, 32, 4, PortKind::Mpi);
        check_variant(16, 16, 2, PortKind::Tcp);
    }

    #[test]
    fn matches_serial_non_pow2_all_ports() {
        // 12×96 over 4 localities: 3×96 slabs, 24-column chunks — every
        // row length is mixed-radix.
        for kind in PortKind::ALL {
            check_variant(12, 96, 4, kind);
        }
    }

    #[test]
    fn single_locality() {
        check_variant(8, 8, 1, PortKind::Lci);
    }

    #[test]
    fn eight_localities() {
        check_variant(32, 32, 8, PortKind::Lci);
    }

    #[test]
    fn rendezvous_sized_chunks_over_mpi() {
        // 128×256 on 2 parts → chunks of 64×128 complex = 64 KiB > eager.
        check_variant(128, 256, 2, PortKind::Mpi);
    }

    #[test]
    fn tiny_wire_chunks_all_ports() {
        // Small chunk policy: each per-root message (4×8 complex =
        // 256 B) splits into four 64 B wire chunks placed on arrival.
        use crate::collectives::ChunkPolicy;
        for kind in PortKind::ALL {
            let (rows, cols, parts) = (16, 32, 4);
            let cluster = Cluster::new(parts, kind, None).unwrap();
            let pieces = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.set_chunk_policy(ChunkPolicy::new(64, 2));
                let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
                run(&comm, &slab, 1, &NativeRowFft).0
            });
            let mut assembled = Vec::with_capacity(rows * cols);
            for p in pieces {
                assembled.extend(p);
            }
            let reference = serial_fft2_transposed(&Slab::whole(rows, cols).data, rows, cols);
            let err = rel_error(&assembled, &reference);
            assert!(err < 1e-4, "rel err {err} ({kind})");
        }
    }

    #[test]
    fn async_matches_blocking_bitwise_all_ports() {
        // Identical arithmetic, different schedule: the async task graph
        // must agree with the blocking run to the bit, on a
        // non-power-of-two grid with multi-chunk bands.
        use crate::collectives::ChunkPolicy;
        let (rows, cols, parts) = (12, 24, 4);
        for kind in PortKind::ALL {
            let run_mode = |async_mode: bool| {
                let cluster = Cluster::new(parts, kind, None).unwrap();
                cluster.run(|ctx| {
                    let comm = Communicator::from_ctx(ctx);
                    comm.set_chunk_policy(ChunkPolicy::new(96, 2));
                    let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
                    if async_mode {
                        run_async(&comm, &slab, 1, &NativeRowFft).0
                    } else {
                        run(&comm, &slab, 1, &NativeRowFft).0
                    }
                })
            };
            assert_eq!(run_mode(false), run_mode(true), "{kind}");
        }
    }

    #[test]
    fn async_single_locality_and_single_band() {
        let cluster = Cluster::new(1, PortKind::Lci, None).unwrap();
        let pieces = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(8, 8, 1, ctx.rank);
            let (out, t) = run_async(&comm, &slab, 1, &NativeRowFft);
            assert_eq!(t.overlap_us, 0.0, "nothing to overlap on one rank");
            out
        });
        let reference = serial_fft2_transposed(&Slab::whole(8, 8).data, 8, 8);
        assert!(rel_error(&pieces[0], &reference) < 1e-4);
    }

    #[test]
    fn async_matches_serial_tiny_bands_all_ports() {
        use crate::collectives::ChunkPolicy;
        for kind in PortKind::ALL {
            let (rows, cols, parts) = (16, 32, 4);
            let cluster = Cluster::new(parts, kind, None).unwrap();
            let pieces = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                // 64 B < one chunk row (8 cols × 8 B): clamps to one row
                // per wire chunk — four bands per destination.
                comm.set_chunk_policy(ChunkPolicy::new(64, 2));
                let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
                run_async(&comm, &slab, 1, &NativeRowFft).0
            });
            let mut assembled = Vec::with_capacity(rows * cols);
            for p in pieces {
                assembled.extend(p);
            }
            let reference = serial_fft2_transposed(&Slab::whole(rows, cols).data, rows, cols);
            let err = rel_error(&assembled, &reference);
            assert!(err < 1e-4, "rel err {err} ({kind})");
        }
    }

    #[test]
    fn hidden_us_window_arithmetic() {
        use std::time::Duration;
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_micros(100);
        let t2 = t0 + Duration::from_micros(200);
        assert_eq!(hidden_us(t1, t2, t0), 0.0, "until before segment");
        let full = hidden_us(t1, t2, t2 + Duration::from_micros(50));
        assert!((full - 100.0).abs() < 1.0, "whole segment hidden: {full}");
        let half = hidden_us(t1, t2, t1 + Duration::from_micros(40));
        assert!((half - 40.0).abs() < 1.0, "partial overlap: {half}");
    }

    #[test]
    fn matches_all_to_all_variant_bitwise() {
        // Both variants perform the identical arithmetic — results must
        // match exactly, not just to tolerance.
        let (rows, cols, parts) = (16, 16, 4);
        let cluster = Cluster::new(parts, PortKind::Lci, None).unwrap();
        let scatter_out = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
            run(&comm, &slab, 1, &NativeRowFft).0
        });
        let cluster2 = Cluster::new(parts, PortKind::Lci, None).unwrap();
        let a2a_out = cluster2.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
            crate::dist_fft::all_to_all_variant::run(
                &comm,
                &slab,
                crate::collectives::AllToAllAlgo::Linear,
                1,
                &NativeRowFft,
            )
            .0
        });
        assert_eq!(scatter_out, a2a_out);
    }
}
