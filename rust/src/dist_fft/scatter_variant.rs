//! Variant B — *N-scatter* with transpose/communication overlap
//! (paper Fig. 5, the paper's proposed improvement).
//!
//! The all-to-all is replaced by N scatter collectives, one rooted at
//! each locality. Because each scatter completes independently, a
//! receiver transposes every chunk *the moment it arrives* instead of
//! waiting for the full exchange — "the arriving data chunks can be
//! transposed as soon as they are received" (§3). The receive loop polls
//! all outstanding roots and interleaves placement work with waiting,
//! which is where the overlap (and the win over Fig. 4) comes from.
//!
//! The overlap granularity is the communicator's
//! [`crate::collectives::ChunkPolicy`]: per-root payloads ship as
//! pipelined zero-copy wire chunks ([`Payload::slice`] views drained by
//! the chunk send pool), and the poll loop places each *wire chunk* as
//! it lands — chunk *k* is unpacked while chunk *k+1* is still on the
//! wire, even within a single root's message.

use super::driver::{RowFft, StepTimings};
use super::partition::Slab;
use super::transpose::{place_chunk_slice_transposed, place_chunk_transposed};
use crate::collectives::Communicator;
use crate::fft::complex::{from_le_bytes, Complex32};
use crate::hpx::parcel::Payload;
use std::time::Instant;

/// Run the four-step distributed FFT with N overlapped scatters.
pub fn run(
    comm: &Communicator,
    slab: &Slab,
    nthreads: usize,
    engine: &dyn RowFft,
) -> (Vec<Complex32>, StepTimings) {
    let n = comm.size();
    let me = comm.rank();
    let lr = slab.local_rows();
    let cw = Slab::cols_per_chunk(slab.global_cols, n);
    let r_total = slab.global_rows;
    let mut timings = StepTimings::default();
    let t_start = Instant::now();

    // Step 1: row FFTs (length C).
    let t0 = Instant::now();
    let mut work = slab.data.clone();
    engine.fft_rows(&mut work, slab.global_cols, nthreads);
    timings.fft1_us = t0.elapsed().as_secs_f64() * 1e6;

    // Steps 2+3 fused: N chunk-pipelined scatters; transpose each wire
    // chunk on arrival.
    const ELEM: usize = std::mem::size_of::<Complex32>();
    comm.set_chunk_policy(comm.chunk_policy().aligned(ELEM));
    let policy = comm.chunk_policy();
    let t0 = Instant::now();
    let mut transpose_spent = 0.0f64;
    let tags = comm.scatter_chunk_tags(n);
    let tmp = Slab {
        global_rows: slab.global_rows,
        global_cols: slab.global_cols,
        parts: slab.parts,
        rank: slab.rank,
        data: work,
    }; // §Perf: field-wise construction — `..slab.clone()` would clone and
       // immediately drop the slab's full data buffer.
    let mut next = vec![Complex32::ZERO; cw * r_total];

    // Every rank derives the transfer size from the slab geometry, so
    // the wire carries no length headers — just the chunks themselves
    // (the known-size chunked protocol).
    let chunk_bytes_total = lr * cw * ELEM;
    let wire_chunks = policy.n_chunks(chunk_bytes_total);

    // Post my own scatter (root = me): ship chunk j to locality j as
    // pipelined wire chunks on the send pool.
    let mut own_chunk: Option<Vec<Complex32>> = None;
    let mut sends_pending = Vec::new();
    for dst in 0..n {
        if dst == me {
            own_chunk = Some(tmp.extract_chunk(dst));
        } else {
            // Single-pass wire serialization (§Perf).
            sends_pending.append(&mut comm.send_chunked_sized(
                dst,
                tags[me],
                Payload::new(tmp.extract_chunk_bytes(dst)),
            ));
        }
    }

    // My own chunk is "received" immediately — transpose it first (free
    // overlap while peers' chunks are in flight).
    {
        let tt = Instant::now();
        let chunk = own_chunk.expect("own chunk extracted");
        place_chunk_transposed(&chunk, lr, cw, &mut next, r_total, me * lr);
        transpose_spent += tt.elapsed().as_secs_f64() * 1e6;
    }

    // Poll the remaining roots; place whichever *wire chunk* lands
    // first, consuming each root's chunks in offset order.
    let mut pending: Vec<(usize, usize)> = // (root, next wire-chunk index)
        (0..n).filter(|&r| r != me).map(|root| (root, 0)).collect();
    while !pending.is_empty() {
        let mut progressed = false;
        let mut i = 0;
        while i < pending.len() {
            let (root, next_chunk) = &mut pending[i];
            while *next_chunk < wire_chunks {
                let Some(payload) = comm.try_recv_chunk(*root, tags[*root], *next_chunk)
                else {
                    break;
                };
                let tt = Instant::now();
                let elems = from_le_bytes(payload.as_bytes());
                place_chunk_slice_transposed(
                    &elems,
                    *next_chunk * policy.chunk_bytes / ELEM,
                    lr,
                    cw,
                    &mut next,
                    r_total,
                    *root * lr,
                );
                transpose_spent += tt.elapsed().as_secs_f64() * 1e6;
                *next_chunk += 1;
                progressed = true;
            }
            if *next_chunk >= wire_chunks {
                pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if !progressed {
            std::hint::spin_loop();
            std::thread::yield_now();
        }
    }
    for f in sends_pending {
        f.get();
    }
    timings.comm_us = t0.elapsed().as_secs_f64() * 1e6;
    timings.transpose_us = transpose_spent; // informational: overlapped inside comm_us

    // Step 4: row FFTs of the transposed slab (length R).
    let t0 = Instant::now();
    engine.fft_rows(&mut next, r_total, nthreads);
    timings.fft2_us = t0.elapsed().as_secs_f64() * 1e6;

    timings.total_us = t_start.elapsed().as_secs_f64() * 1e6;
    (next, timings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist_fft::driver::NativeRowFft;
    use crate::dist_fft::verify::{rel_error, serial_fft2_transposed};
    use crate::hpx::runtime::Cluster;
    use crate::parcelport::PortKind;

    fn check_variant(rows: usize, cols: usize, parts: usize, kind: PortKind) {
        let cluster = Cluster::new(parts, kind, None).unwrap();
        let pieces = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
            let (out, _t) = run(&comm, &slab, 1, &NativeRowFft);
            out
        });
        let mut assembled = Vec::with_capacity(rows * cols);
        for p in pieces {
            assembled.extend(p);
        }
        let reference = serial_fft2_transposed(&Slab::whole(rows, cols).data, rows, cols);
        let err = rel_error(&assembled, &reference);
        assert!(err < 1e-4, "rel err {err} ({kind} {parts} parts)");
    }

    #[test]
    fn matches_serial_all_ports() {
        check_variant(16, 32, 4, PortKind::Lci);
        check_variant(16, 32, 4, PortKind::Mpi);
        check_variant(16, 16, 2, PortKind::Tcp);
    }

    #[test]
    fn matches_serial_non_pow2_all_ports() {
        // 12×96 over 4 localities: 3×96 slabs, 24-column chunks — every
        // row length is mixed-radix.
        for kind in PortKind::ALL {
            check_variant(12, 96, 4, kind);
        }
    }

    #[test]
    fn single_locality() {
        check_variant(8, 8, 1, PortKind::Lci);
    }

    #[test]
    fn eight_localities() {
        check_variant(32, 32, 8, PortKind::Lci);
    }

    #[test]
    fn rendezvous_sized_chunks_over_mpi() {
        // 128×256 on 2 parts → chunks of 64×128 complex = 64 KiB > eager.
        check_variant(128, 256, 2, PortKind::Mpi);
    }

    #[test]
    fn tiny_wire_chunks_all_ports() {
        // Small chunk policy: each per-root message (4×8 complex =
        // 256 B) splits into four 64 B wire chunks placed on arrival.
        use crate::collectives::ChunkPolicy;
        for kind in PortKind::ALL {
            let (rows, cols, parts) = (16, 32, 4);
            let cluster = Cluster::new(parts, kind, None).unwrap();
            let pieces = cluster.run(|ctx| {
                let comm = Communicator::from_ctx(ctx);
                comm.set_chunk_policy(ChunkPolicy::new(64, 2));
                let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
                run(&comm, &slab, 1, &NativeRowFft).0
            });
            let mut assembled = Vec::with_capacity(rows * cols);
            for p in pieces {
                assembled.extend(p);
            }
            let reference = serial_fft2_transposed(&Slab::whole(rows, cols).data, rows, cols);
            let err = rel_error(&assembled, &reference);
            assert!(err < 1e-4, "rel err {err} ({kind})");
        }
    }

    #[test]
    fn matches_all_to_all_variant_bitwise() {
        // Both variants perform the identical arithmetic — results must
        // match exactly, not just to tolerance.
        let (rows, cols, parts) = (16, 16, 4);
        let cluster = Cluster::new(parts, PortKind::Lci, None).unwrap();
        let scatter_out = cluster.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
            run(&comm, &slab, 1, &NativeRowFft).0
        });
        let cluster2 = Cluster::new(parts, PortKind::Lci, None).unwrap();
        let a2a_out = cluster2.run(|ctx| {
            let comm = Communicator::from_ctx(ctx);
            let slab = Slab::synthetic(rows, cols, parts, ctx.rank);
            crate::dist_fft::all_to_all_variant::run(
                &comm,
                &slab,
                crate::collectives::AllToAllAlgo::Linear,
                1,
                &NativeRowFft,
            )
            .0
        });
        assert_eq!(scatter_out, a2a_out);
    }
}
