//! `TransformRequest` — the single front door to every distributed
//! transform.
//!
//! Historically each transform shape had its own divergent entry
//! points: `driver::{run, run_on}` for 2-D slabs, the variant-level
//! `run_input`/`run_async_input`, and `pencil::{run, run_on}` for the
//! 3-D pencil path. This module collapses them behind one builder:
//!
//! ```
//! use hpx_fft::prelude::*;
//!
//! // 2-D slab transform, all defaults.
//! let report = TransformRequest::grid(32, 32).build().unwrap().run().unwrap();
//! assert!(report.rel_error.unwrap() < 1e-4);
//!
//! // 3-D pencil transform, real input, async execution.
//! let report = TransformRequest::grid3(Grid3::new(12, 8, 24))
//!     .proc_grid(ProcGrid::new(2, 2))
//!     .domain(Domain::Real)
//!     .exec(ExecutionMode::Async)
//!     .threads(1)
//!     .build()
//!     .unwrap()
//!     .run()
//!     .unwrap();
//! assert!(report.rel_error.unwrap() < 1e-4);
//! ```
//!
//! All validation happens at [`TransformRequest::build`], with the same
//! actionable error strings the old entry points produced — a built
//! [`Transform`] is known-runnable up to cluster-size mismatches. The
//! old entry points survive as `#[deprecated]` shims over the same
//! internals.

use super::driver::{
    self, ComputeEngine, DistFftConfig, Domain, ExecutionMode, StepTimings, Variant,
};
use super::grid3::{Grid3, ProcGrid};
use super::pencil::{self, Pencil3Config, PencilTimings};
use crate::collectives::{AllToAllAlgo, ChunkPolicy};
use crate::config::TransformSpec;
use crate::fft::complex::Complex32;
use crate::hpx::runtime::Cluster;
use crate::parcelport::{NetModel, PortKind, PortStatsSnapshot};

/// The transform's shape: a 2-D slab grid or a 3-D pencil grid.
#[derive(Clone, Debug)]
enum Shape {
    /// `rows × cols` slab decomposition over `localities` ranks.
    Plane { rows: usize, cols: usize },
    /// `n0 × n1 × n2` pencil decomposition over a `Pr × Pc` process grid.
    Pencil { grid: Grid3 },
}

/// Builder for one distributed transform — 2-D or 3-D, complex or real,
/// blocking or async, over any parcelport (see the [module docs]
/// for examples).
///
/// Start from [`TransformRequest::grid`] (2-D) or
/// [`TransformRequest::grid3`] (3-D), chain setters, and call
/// [`build`](Self::build); shape-inapplicable settings (e.g.
/// [`variant`](Self::variant) on a 3-D request) are rejected there with
/// actionable errors.
///
/// [module docs]: self
#[derive(Clone, Debug)]
pub struct TransformRequest {
    shape: Shape,
    spec: TransformSpec,
    variant: Option<Variant>,
    algo: Option<AllToAllAlgo>,
    localities: Option<usize>,
    proc: Option<ProcGrid>,
    collect_outputs: bool,
    trace: bool,
}

impl TransformRequest {
    /// A 2-D `rows × cols` slab transform (defaults: 4 localities,
    /// scatter variant, [`TransformSpec::default`] execution settings).
    pub fn grid(rows: usize, cols: usize) -> Self {
        Self {
            shape: Shape::Plane { rows, cols },
            spec: TransformSpec::default(),
            variant: None,
            algo: None,
            localities: None,
            proc: None,
            collect_outputs: false,
            trace: false,
        }
    }

    /// A 3-D `n0 × n1 × n2` pencil transform (defaults: 2×2 process
    /// grid, [`TransformSpec::default`] execution settings).
    pub fn grid3(grid: Grid3) -> Self {
        Self {
            shape: Shape::Pencil { grid },
            spec: TransformSpec::default(),
            variant: None,
            algo: None,
            localities: None,
            proc: None,
            collect_outputs: false,
            trace: false,
        }
    }

    /// Replace the full shared execution-settings block at once (port,
    /// chunk policy, exec mode, domain, threads, wire model, engine,
    /// verify). Individual setters may still override afterwards.
    pub fn spec(mut self, spec: TransformSpec) -> Self {
        self.spec = spec;
        self
    }

    /// Input domain: complex (c2c) or real (r2c, halved wire bytes).
    pub fn domain(mut self, domain: Domain) -> Self {
        self.spec.domain = domain;
        self
    }

    /// Parcelport backend.
    pub fn port(mut self, port: PortKind) -> Self {
        self.spec.port = port;
        self
    }

    /// Blocking lock-step collectives vs the future-chained task graph.
    pub fn exec(mut self, exec: ExecutionMode) -> Self {
        self.spec.exec = exec;
        self
    }

    /// Wire-chunking policy for the run's communicators.
    pub fn chunk(mut self, chunk: ChunkPolicy) -> Self {
        self.spec.chunk = chunk;
        self
    }

    /// Communication variant — 2-D requests only (the pencil path
    /// always runs its chunk-pipelined exchange rounds).
    pub fn variant(mut self, variant: Variant) -> Self {
        self.variant = Some(variant);
        self
    }

    /// All-to-all algorithm — 2-D [`Variant::AllToAll`] requests only.
    pub fn algo(mut self, algo: AllToAllAlgo) -> Self {
        self.algo = Some(algo);
        self
    }

    /// Number of participating localities — 2-D requests only (3-D
    /// requests derive it from [`proc_grid`](Self::proc_grid)).
    pub fn localities(mut self, n: usize) -> Self {
        self.localities = Some(n);
        self
    }

    /// `Pr × Pc` process grid — 3-D requests only.
    pub fn proc_grid(mut self, proc: ProcGrid) -> Self {
        self.proc = Some(proc);
        self
    }

    /// Worker threads per locality for the row-FFT phases.
    pub fn threads(mut self, n: usize) -> Self {
        self.spec.threads_per_locality = n;
        self
    }

    /// Optional hybrid wire model.
    pub fn net(mut self, net: Option<NetModel>) -> Self {
        self.spec.net = net;
        self
    }

    /// Row-FFT compute engine.
    pub fn engine(mut self, engine: ComputeEngine) -> Self {
        self.spec.engine = engine;
        self
    }

    /// Compare the distributed result against the serial reference.
    pub fn verify(mut self, verify: bool) -> Self {
        self.spec.verify = verify;
        self
    }

    /// Return each rank's raw spectral piece in
    /// [`TransformReport::outputs`] — the bitwise-comparison hook the
    /// stress tests and the service's mismatch audit use.
    pub fn collect_outputs(mut self, collect: bool) -> Self {
        self.collect_outputs = collect;
        self
    }

    /// Capture a span timeline of the run and export it as a Chrome
    /// trace-event JSON file; the path lands in
    /// [`TransformReport::trace_path`]. The capture claims the
    /// process-wide trace session for the duration of the run, so two
    /// traced transforms serialize — do not request a trace from code
    /// that already holds a [`crate::obs::TraceSession`].
    pub fn trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    /// Validate the request and freeze it into a runnable
    /// [`Transform`]. All shape/domain/chunk validation happens here,
    /// with the same actionable error strings the deprecated entry
    /// points produce.
    pub fn build(self) -> anyhow::Result<Transform> {
        let plan = match self.shape {
            Shape::Plane { rows, cols } => {
                anyhow::ensure!(
                    self.proc.is_none(),
                    "proc_grid() applies to 3-D requests only; use localities() to size \
                     a 2-D transform (or start from TransformRequest::grid3)"
                );
                let mut config = DistFftConfig { rows, cols, ..DistFftConfig::default() };
                config.apply_spec(&self.spec);
                if let Some(n) = self.localities {
                    config.localities = n;
                }
                if let Some(v) = self.variant {
                    config.variant = v;
                }
                if let Some(a) = self.algo {
                    config.algo = a;
                }
                driver::validate_config(&config)?;
                Plan::Plane(config)
            }
            Shape::Pencil { grid } => {
                anyhow::ensure!(
                    self.variant.is_none() && self.algo.is_none(),
                    "variant()/algo() apply to 2-D requests only; the pencil path always \
                     runs its chunk-pipelined exchange rounds"
                );
                anyhow::ensure!(
                    self.localities.is_none(),
                    "localities() applies to 2-D requests only; size a 3-D transform \
                     with proc_grid(ProcGrid::new(pr, pc))"
                );
                let mut config = Pencil3Config { grid, ..Pencil3Config::default() };
                config.apply_spec(&self.spec);
                if let Some(p) = self.proc {
                    config.proc = p;
                }
                pencil::validate_config(&config)?;
                Plan::Pencil(config)
            }
        };
        Ok(Transform { plan, collect_outputs: self.collect_outputs, trace: self.trace })
    }
}

/// The validated execution plan behind a [`Transform`].
#[derive(Clone, Debug)]
enum Plan {
    Plane(DistFftConfig),
    Pencil(Pencil3Config),
}

/// A validated, runnable transform produced by
/// [`TransformRequest::build`]. Immutable; [`run`](Self::run) it on a
/// fresh cluster, or [`run_on`](Self::run_on) an existing one to reuse
/// its fabric across repetitions (what the figure harnesses do).
#[derive(Clone, Debug)]
pub struct Transform {
    plan: Plan,
    collect_outputs: bool,
    trace: bool,
}

impl Transform {
    /// Number of localities this transform occupies.
    pub fn localities(&self) -> usize {
        match &self.plan {
            Plan::Plane(c) => c.localities,
            Plan::Pencil(c) => c.proc.n(),
        }
    }

    /// Parcelport backend the transform runs on.
    pub fn port(&self) -> PortKind {
        match &self.plan {
            Plan::Plane(c) => c.port,
            Plan::Pencil(c) => c.port,
        }
    }

    /// Optional hybrid wire model.
    pub fn net(&self) -> Option<NetModel> {
        match &self.plan {
            Plan::Plane(c) => c.net,
            Plan::Pencil(c) => c.net,
        }
    }

    /// The validated 2-D configuration, if this is a slab transform.
    pub(crate) fn plane_config(&self) -> Option<&DistFftConfig> {
        match &self.plan {
            Plan::Plane(c) => Some(c),
            Plan::Pencil(_) => None,
        }
    }

    /// The validated 3-D configuration, if this is a pencil transform.
    pub(crate) fn pencil_config(&self) -> Option<&Pencil3Config> {
        match &self.plan {
            Plan::Plane(_) => None,
            Plan::Pencil(c) => Some(c),
        }
    }

    /// Whether the request asked for raw per-rank outputs in the report.
    pub(crate) fn collects_outputs(&self) -> bool {
        self.collect_outputs
    }

    /// Run end to end on a fresh cluster.
    pub fn run(&self) -> anyhow::Result<TransformReport> {
        let cluster = Cluster::new(self.localities(), self.port(), self.net())?;
        self.run_on(&cluster)
    }

    /// Run on an existing cluster (benchmarks reuse fabrics across
    /// reps; the cluster must span exactly
    /// [`localities`](Self::localities) ranks). When the request asked
    /// for a [`trace`](TransformRequest::trace), the run executes under
    /// the process-wide trace session and the exported timeline's path
    /// lands in [`TransformReport::trace_path`].
    pub fn run_on(&self, cluster: &Cluster) -> anyhow::Result<TransformReport> {
        if !self.trace {
            return self.run_on_untraced(cluster);
        }
        let session = crate::obs::session();
        let result = self.run_on_untraced(cluster);
        let events = session.finish();
        let mut report = result?;
        let path = trace_output_path();
        crate::obs::chrome::export(&events, &path)
            .map_err(|e| anyhow::anyhow!("writing trace file {path}: {e}"))?;
        report.trace_path = Some(path);
        Ok(report)
    }

    /// [`run_on`](Self::run_on) without the trace-session wrapper.
    fn run_on_untraced(&self, cluster: &Cluster) -> anyhow::Result<TransformReport> {
        match &self.plan {
            Plan::Plane(config) => {
                let (report, pieces) = driver::run_on_impl(cluster, config)?;
                Ok(TransformReport {
                    summary: report.config_summary,
                    timings: TransformTimings::Plane {
                        per_rank: report.per_rank,
                        critical_path: report.critical_path,
                    },
                    rel_error: report.rel_error,
                    stats: report.stats,
                    outputs: self.collect_outputs.then_some(pieces),
                    trace_path: None,
                })
            }
            Plan::Pencil(config) => {
                let (report, pieces) = pencil::run_on_collect(cluster, config)?;
                Ok(TransformReport {
                    summary: report.config_summary,
                    timings: TransformTimings::Pencil {
                        per_rank: report.per_rank,
                        critical_path: report.critical_path,
                    },
                    rel_error: report.rel_error,
                    stats: report.stats,
                    outputs: self.collect_outputs.then_some(pieces),
                    trace_path: None,
                })
            }
        }
    }
}

/// Collision-free output path for a traced transform's timeline:
/// `bench_out/transform-<pid>-<seq>.trace.json` (the sequence counter
/// disambiguates traced runs within one process).
fn trace_output_path() -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    format!("bench_out/transform-{}-{seq}.trace.json", std::process::id())
}

/// Per-shape timing detail of a [`TransformReport`].
#[derive(Clone, Debug)]
pub enum TransformTimings {
    /// 2-D slab transform: four-step timings per rank.
    Plane {
        /// Per-locality step timings, rank order.
        per_rank: Vec<StepTimings>,
        /// Element-wise max across localities.
        critical_path: StepTimings,
    },
    /// 3-D pencil transform: five-phase timings per rank.
    Pencil {
        /// Per-locality phase timings, rank order.
        per_rank: Vec<PencilTimings>,
        /// Element-wise max across localities.
        critical_path: PencilTimings,
    },
}

impl TransformTimings {
    /// Critical-path end-to-end wall time, µs.
    pub fn total_us(&self) -> f64 {
        match self {
            TransformTimings::Plane { critical_path, .. } => critical_path.total_us,
            TransformTimings::Pencil { critical_path, .. } => critical_path.total_us,
        }
    }

    /// The 2-D critical-path step timings, if this is a slab transform.
    pub fn plane_critical_path(&self) -> Option<&StepTimings> {
        match self {
            TransformTimings::Plane { critical_path, .. } => Some(critical_path),
            TransformTimings::Pencil { .. } => None,
        }
    }

    /// The 3-D critical-path phase timings, if this is a pencil
    /// transform.
    pub fn pencil_critical_path(&self) -> Option<&PencilTimings> {
        match self {
            TransformTimings::Plane { .. } => None,
            TransformTimings::Pencil { critical_path, .. } => Some(critical_path),
        }
    }

    /// Critical-path comm/compute overlap, µs (0 in blocking mode).
    pub fn overlap_us(&self) -> f64 {
        match self {
            TransformTimings::Plane { critical_path, .. } => critical_path.overlap_us,
            TransformTimings::Pencil { critical_path, .. } => critical_path.overlap_us,
        }
    }
}

/// Unified execution report of one transform, whatever its shape — what
/// [`Transform::run`]/[`Transform::run_on`] return and what the service
/// hands back per job.
#[derive(Clone, Debug)]
pub struct TransformReport {
    /// One-line description of the executed configuration.
    pub summary: String,
    /// Per-shape timing detail.
    pub timings: TransformTimings,
    /// Relative L2 error vs. the serial reference (if verified).
    pub rel_error: Option<f64>,
    /// Traffic accounted during the run. From the cluster driver this
    /// is the fabric-global diff; from the service it is the job's own
    /// scoped counters (see `Communicator::with_stats_scope`).
    pub stats: PortStatsSnapshot,
    /// Each rank's raw spectral piece, rank order — present only when
    /// the request asked for [`TransformRequest::collect_outputs`].
    pub outputs: Option<Vec<Vec<Complex32>>>,
    /// Path of the exported Chrome trace-event JSON timeline — present
    /// only when the request asked for [`TransformRequest::trace`] (and
    /// only on the single-shot path; service jobs share one fabric, so
    /// per-job capture would interleave tenants).
    pub trace_path: Option<String>,
}

impl TransformReport {
    /// Critical-path end-to-end wall time, µs.
    pub fn total_us(&self) -> f64 {
        self.timings.total_us()
    }

    /// Critical-path comm/compute overlap, µs.
    pub fn overlap_us(&self) -> f64 {
        self.timings.overlap_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plane_request_runs_and_verifies() {
        let report = TransformRequest::grid(32, 32).build().unwrap().run().unwrap();
        assert!(report.rel_error.unwrap() < 1e-4);
        assert!(report.total_us() > 0.0);
        assert!(report.stats.msgs_sent > 0);
        assert!(report.outputs.is_none(), "outputs only on request");
        match &report.timings {
            TransformTimings::Plane { per_rank, .. } => assert_eq!(per_rank.len(), 4),
            other => panic!("expected plane timings, got {other:?}"),
        }
    }

    #[test]
    fn pencil_request_runs_and_verifies() {
        let report = TransformRequest::grid3(Grid3::new(12, 8, 24))
            .proc_grid(ProcGrid::new(2, 2))
            .threads(1)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!(report.rel_error.unwrap() < 1e-4);
        match &report.timings {
            TransformTimings::Pencil { per_rank, .. } => assert_eq!(per_rank.len(), 4),
            other => panic!("expected pencil timings, got {other:?}"),
        }
    }

    #[test]
    fn build_rejects_indivisible_plane_grid() {
        let err = TransformRequest::grid(30, 32).build().unwrap_err().to_string();
        assert!(err.contains("divide evenly"), "{err}");
    }

    #[test]
    fn build_rejects_variant_on_pencil() {
        let err = TransformRequest::grid3(Grid3::new(8, 8, 8))
            .variant(Variant::AllToAll)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("2-D requests only"), "{err}");
    }

    #[test]
    fn build_rejects_proc_grid_on_plane() {
        let err = TransformRequest::grid(16, 16)
            .proc_grid(ProcGrid::new(2, 2))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("3-D requests only"), "{err}");
    }

    #[test]
    fn build_rejects_localities_on_pencil() {
        let err = TransformRequest::grid3(Grid3::new(8, 8, 8))
            .localities(4)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("proc_grid"), "{err}");
    }

    #[test]
    fn build_rejects_real_domain_odd_cols() {
        let err = TransformRequest::grid(16, 27)
            .domain(Domain::Real)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("even column count"), "{err}");
    }

    #[test]
    fn build_rejects_zero_chunk_policy() {
        let err = TransformRequest::grid(16, 16)
            .chunk(ChunkPolicy { chunk_bytes: 0, inflight: 4 })
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("chunk policy must be positive"), "{err}");
    }

    #[test]
    #[allow(deprecated)]
    fn request_matches_deprecated_driver_bitwise() {
        // The new front door must produce byte-identical spectra to the
        // old entry points — it routes through the same internals.
        let report = TransformRequest::grid(16, 16)
            .localities(2)
            .threads(1)
            .collect_outputs(true)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let config = DistFftConfig {
            rows: 16,
            cols: 16,
            localities: 2,
            threads_per_locality: 1,
            ..Default::default()
        };
        let cluster = Cluster::new(2, config.port, config.net).unwrap();
        let (_, pieces) = driver::run_on_impl(&cluster, &config).unwrap();
        assert_eq!(report.outputs.unwrap(), pieces);
    }

    #[test]
    fn request_spec_bulk_apply() {
        let spec = TransformSpec {
            port: PortKind::Mpi,
            exec: ExecutionMode::Async,
            threads_per_locality: 1,
            ..Default::default()
        };
        let report =
            TransformRequest::grid(16, 16).spec(spec).localities(2).build().unwrap().run().unwrap();
        assert!(report.summary.contains("mpi port"), "{}", report.summary);
        assert!(report.summary.contains("async exec"), "{}", report.summary);
    }
}
