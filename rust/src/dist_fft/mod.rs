//! Distributed 2-D FFT — the paper's application (its Fig. 1).
//!
//! The global `R × C` complex grid is slab-decomposed by rows over N
//! localities. `R` and `C` may be any lengths divisible by N (the
//! planner is mixed-radix, so e.g. 12×96 slabs run as readily as the
//! paper's power-of-two grids). Each locality executes the four steps:
//!
//! 1. **FFT** every local row (length `C`),
//! 2. **communicate**: split the local slab column-wise into N chunks and
//!    ship chunk `j` to locality `j` — `(1 − 1/N)` of the local data
//!    crosses the network,
//! 3. **transpose** each received chunk into the new local slab,
//! 4. **FFT** every row of the new slab (length `R`).
//!
//! The result is the 2-D FFT in *transposed* distributed layout (the
//! standard distributed-FFT convention — FFTW's `FFTW_MPI_TRANSPOSED_OUT`).
//!
//! Two communication variants, exactly as the paper benchmarks them:
//!
//! - [`all_to_all_variant`]: one synchronized all-to-all collective
//!   (Fig. 4). The transpose (step 3) cannot start until the collective
//!   completes — except with `AllToAllAlgo::PairwiseChunked`, which
//!   streams policy-sized wire chunks and transposes each on arrival.
//! - [`scatter_variant`]: N scatter collectives, one rooted at each
//!   locality (Fig. 5). Arriving chunks are transposed immediately,
//!   hiding transpose work behind the remaining communication; with the
//!   chunked wire protocol the overlap is per *wire chunk*
//!   ([`crate::collectives::ChunkPolicy`]), not per whole message.
//!
//! Each variant runs in either execution mode
//! ([`driver::ExecutionMode`], the CLI's `--exec` axis): *blocking*
//! (lock-step phases) or *async* (a future-chained task graph that
//! streams wire chunks out of the first FFT, places arrivals while later
//! chunks fly, and runs the second FFT as a continuation over the
//! draining sends, reporting the hidden wall time as
//! `StepTimings::overlap_us`).
//!
//! Both variants run in either input **domain** ([`driver::Domain`],
//! the CLI's `--domain` axis): *complex* (c2c, the paper's benchmark)
//! or *real* (r2c — the paper's FFTW3+MPI reference workload), where
//! step 1 packs each real row into a half-spectrum of `C/2` bins
//! ([`crate::fft::real`]), so every transpose round moves **half** the
//! payload bytes over the same chunked wire protocol.
//!
//! [`verify`] pins both against a serial reference on every port.
//!
//! Beyond the paper's 2-D slab benchmark, [`pencil`] generalizes the
//! same collective patterns to a distributed **3-D FFT**: an
//! `n0×n1×n2` grid on a `Pr×Pc` process grid ([`grid3`]), executed as
//! FFT(z) → row-communicator transpose → FFT(y) → column-communicator
//! transpose → FFT(x), with the row/column communicators built by
//! [`crate::collectives::Communicator::split`].

pub mod driver;
pub mod grid3;
pub mod partition;
pub mod pencil;
pub mod request;
pub mod transpose;
pub mod verify;

pub mod all_to_all_variant;
pub mod scatter_variant;

pub use driver::{
    ComputeEngine, DistFftConfig, DistFftReport, Domain, ExecutionMode, StepTimings, Variant,
};
pub use grid3::{Grid3, PencilDims, ProcGrid};
pub use partition::{FftInput, RealSlab, Slab};
pub use pencil::{Pencil3Config, Pencil3Report, PencilTimings};
pub use request::{Transform, TransformReport, TransformRequest, TransformTimings};
