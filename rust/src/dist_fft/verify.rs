//! Serial reference for the distributed transform.
//!
//! Computes the same transposed-layout 2-D FFT a distributed run
//! produces, entirely on one thread with the native kernel: row FFTs →
//! transpose → row FFTs. Used by tests and the CLI's `--verify` flag.

use super::transpose::transpose;
use crate::fft::complex::Complex32;
use crate::fft::plan::{Direction, PlanCache};

/// Serial transposed-output 2-D FFT of a row-major `rows × cols` grid.
/// Output is `cols × rows` (frequency-domain, transposed layout).
pub fn serial_fft2_transposed(data: &[Complex32], rows: usize, cols: usize) -> Vec<Complex32> {
    assert_eq!(data.len(), rows * cols);
    let mut work = data.to_vec();

    // Step 1: FFT each row (length cols).
    let plan_c = PlanCache::global().plan(cols, Direction::Forward);
    plan_c.execute_rows(&mut work);

    // Step 2+3: full transpose (what the communication + chunk transposes
    // accomplish across localities).
    let mut t = transpose(&work, rows, cols);

    // Step 4: FFT each row of the transposed grid (length rows).
    let plan_r = PlanCache::global().plan(rows, Direction::Forward);
    plan_r.execute_rows(&mut t);
    t
}

/// Max |Δ| between two complex buffers, as interleaved f32 distance.
pub fn max_error(a: &[Complex32], b: &[Complex32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x.re - y.re).abs().max((x.im - y.im).abs()))
        .fold(0.0, f32::max)
}

/// Relative L2 error between complex buffers.
pub fn rel_error(a: &[Complex32], b: &[Complex32]) -> f64 {
    let fa: Vec<f32> = a.iter().flat_map(|c| [c.re, c.im]).collect();
    let fb: Vec<f32> = b.iter().flat_map(|c| [c.re, c.im]).collect();
    crate::util::testkit::rel_l2_error(&fa, &fb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist_fft::partition::Slab;
    use crate::fft::dft::dft;

    /// Oracle-grade 2-D DFT (transposed output), O(n³)-ish — tiny sizes only.
    fn oracle_fft2_transposed(data: &[Complex32], rows: usize, cols: usize) -> Vec<Complex32> {
        // Row DFTs.
        let mut work: Vec<Complex32> = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            work.extend(dft(&data[r * cols..(r + 1) * cols]));
        }
        // Transpose.
        let t = transpose(&work, rows, cols);
        // Row DFTs again.
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..cols {
            out.extend(dft(&t[r * rows..(r + 1) * rows]));
        }
        out
    }

    #[test]
    fn matches_oracle() {
        let grid = Slab::whole(8, 16).data;
        let fast = serial_fft2_transposed(&grid, 8, 16);
        let slow = oracle_fft2_transposed(&grid, 8, 16);
        assert!(rel_error(&fast, &slow) < 1e-4, "rel err {}", rel_error(&fast, &slow));
    }

    #[test]
    fn matches_oracle_non_pow2() {
        // Mixed-radix rows and columns (12 = 4·3, 20 = 4·5).
        let grid = Slab::whole(12, 20).data;
        let fast = serial_fft2_transposed(&grid, 12, 20);
        let slow = oracle_fft2_transposed(&grid, 12, 20);
        assert!(rel_error(&fast, &slow) < 1e-4, "rel err {}", rel_error(&fast, &slow));
    }

    #[test]
    fn impulse_transforms_to_constant() {
        let mut grid = vec![Complex32::ZERO; 4 * 8];
        grid[0] = Complex32::ONE;
        let f = serial_fft2_transposed(&grid, 4, 8);
        for v in f {
            assert!((v.re - 1.0).abs() < 1e-5 && v.im.abs() < 1e-5);
        }
    }

    #[test]
    fn dc_energy() {
        let grid = vec![Complex32::ONE; 8 * 8];
        let f = serial_fft2_transposed(&grid, 8, 8);
        assert!((f[0].re - 64.0).abs() < 1e-3);
        for v in &f[1..] {
            assert!(v.abs() < 1e-3);
        }
    }

    #[test]
    fn rel_error_zero_on_identity() {
        let grid = Slab::whole(4, 4).data;
        assert_eq!(rel_error(&grid, &grid), 0.0);
    }
}
