//! Serial reference for the distributed transforms.
//!
//! Computes the same transposed-layout FFT a distributed run produces,
//! entirely on one thread with the native kernel — 2-D: row FFTs →
//! transpose → row FFTs; 3-D: z FFTs → transpose → y FFTs → transpose →
//! x FFTs. Used by tests and the CLI's `--verify` flag.

use super::grid3::Grid3;
use super::transpose::{place_chunk_transposed, transpose};
use crate::fft::complex::Complex32;
use crate::fft::plan::{Direction, PlanCache};

/// Serial transposed-output 2-D FFT of a row-major `rows × cols` grid.
/// Output is `cols × rows` (frequency-domain, transposed layout).
pub fn serial_fft2_transposed(data: &[Complex32], rows: usize, cols: usize) -> Vec<Complex32> {
    assert_eq!(data.len(), rows * cols);
    let mut work = data.to_vec();

    // Step 1: FFT each row (length cols).
    let plan_c = PlanCache::global().plan(cols, Direction::Forward);
    plan_c.execute_rows(&mut work);

    // Step 2+3: full transpose (what the communication + chunk transposes
    // accomplish across localities).
    let mut t = transpose(&work, rows, cols);

    // Step 4: FFT each row of the transposed grid (length rows).
    let plan_r = PlanCache::global().plan(rows, Direction::Forward);
    plan_r.execute_rows(&mut t);
    t
}

/// Serial transposed-output 3-D FFT of a row-major `[i0][i1][i2]` grid.
/// Output is `[i2][i1][i0]` (frequency-domain, transposed layout) — the
/// global shape of the pencil pipeline's distributed result.
pub fn serial_fft3_transposed(data: &[Complex32], grid: Grid3) -> Vec<Complex32> {
    let (n0, n1, n2) = (grid.n0, grid.n1, grid.n2);
    assert_eq!(data.len(), grid.elems());
    let mut work = data.to_vec();

    // Phase 1: FFT every z-row (length n2).
    PlanCache::global().plan(n2, Direction::Forward).execute_rows(&mut work);

    // Transpose 1: [i0·n1 + i1][i2] → [i2][i0][i1] (what the
    // row-communicator exchange accomplishes across localities).
    let mut t = transpose(&work, n0 * n1, n2);

    // Phase 3: FFT every y-row (length n1).
    PlanCache::global().plan(n1, Direction::Forward).execute_rows(&mut t);

    // Transpose 2: per-i2 slice, [i0][i1] → [i1][i0] (the
    // column-communicator exchange).
    let mut out = vec![Complex32::ZERO; n0 * n1 * n2];
    for z in 0..n2 {
        place_chunk_transposed(
            &t[z * n0 * n1..(z + 1) * n0 * n1],
            n0,
            n1,
            &mut out[z * n0 * n1..(z + 1) * n0 * n1],
            n0,
            0,
        );
    }

    // Phase 5: FFT every x-row (length n0).
    PlanCache::global().plan(n0, Direction::Forward).execute_rows(&mut out);
    out
}

/// Oracle-grade 3-D DFT in the same transposed `[i2][i1][i0]` layout as
/// [`serial_fft3_transposed`]: O(n²) DFTs per axis, f64 accumulation —
/// ground truth for tests, tiny sizes only.
pub fn oracle_fft3_transposed(data: &[Complex32], grid: Grid3) -> Vec<Complex32> {
    use crate::fft::dft::dft;
    let (n0, n1, n2) = (grid.n0, grid.n1, grid.n2);
    assert_eq!(data.len(), grid.elems());
    let mut work: Vec<Complex32> = Vec::with_capacity(grid.elems());
    for r in 0..n0 * n1 {
        work.extend(dft(&data[r * n2..(r + 1) * n2]));
    }
    let t = transpose(&work, n0 * n1, n2); // [i2][i0][i1]
    let mut t2: Vec<Complex32> = Vec::with_capacity(grid.elems());
    for r in 0..n2 * n0 {
        t2.extend(dft(&t[r * n1..(r + 1) * n1]));
    }
    let mut swapped = vec![Complex32::ZERO; grid.elems()]; // [i2][i1][i0]
    for z in 0..n2 {
        place_chunk_transposed(
            &t2[z * n0 * n1..(z + 1) * n0 * n1],
            n0,
            n1,
            &mut swapped[z * n0 * n1..(z + 1) * n0 * n1],
            n0,
            0,
        );
    }
    let mut out: Vec<Complex32> = Vec::with_capacity(grid.elems());
    for r in 0..n2 * n1 {
        out.extend(dft(&swapped[r * n0..(r + 1) * n0]));
    }
    out
}

/// Max |Δ| between two complex buffers, as interleaved f32 distance.
pub fn max_error(a: &[Complex32], b: &[Complex32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x.re - y.re).abs().max((x.im - y.im).abs()))
        .fold(0.0, f32::max)
}

/// Relative L2 error between complex buffers.
pub fn rel_error(a: &[Complex32], b: &[Complex32]) -> f64 {
    let fa: Vec<f32> = a.iter().flat_map(|c| [c.re, c.im]).collect();
    let fb: Vec<f32> = b.iter().flat_map(|c| [c.re, c.im]).collect();
    crate::util::testkit::rel_l2_error(&fa, &fb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist_fft::partition::Slab;
    use crate::fft::dft::dft;

    /// Oracle-grade 2-D DFT (transposed output), O(n³)-ish — tiny sizes only.
    fn oracle_fft2_transposed(data: &[Complex32], rows: usize, cols: usize) -> Vec<Complex32> {
        // Row DFTs.
        let mut work: Vec<Complex32> = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            work.extend(dft(&data[r * cols..(r + 1) * cols]));
        }
        // Transpose.
        let t = transpose(&work, rows, cols);
        // Row DFTs again.
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..cols {
            out.extend(dft(&t[r * rows..(r + 1) * rows]));
        }
        out
    }

    #[test]
    fn matches_oracle() {
        let grid = Slab::whole(8, 16).data;
        let fast = serial_fft2_transposed(&grid, 8, 16);
        let slow = oracle_fft2_transposed(&grid, 8, 16);
        assert!(rel_error(&fast, &slow) < 1e-4, "rel err {}", rel_error(&fast, &slow));
    }

    #[test]
    fn matches_oracle_non_pow2() {
        // Mixed-radix rows and columns (12 = 4·3, 20 = 4·5).
        let grid = Slab::whole(12, 20).data;
        let fast = serial_fft2_transposed(&grid, 12, 20);
        let slow = oracle_fft2_transposed(&grid, 12, 20);
        assert!(rel_error(&fast, &slow) < 1e-4, "rel err {}", rel_error(&fast, &slow));
    }

    #[test]
    fn impulse_transforms_to_constant() {
        let mut grid = vec![Complex32::ZERO; 4 * 8];
        grid[0] = Complex32::ONE;
        let f = serial_fft2_transposed(&grid, 4, 8);
        for v in f {
            assert!((v.re - 1.0).abs() < 1e-5 && v.im.abs() < 1e-5);
        }
    }

    #[test]
    fn dc_energy() {
        let grid = vec![Complex32::ONE; 8 * 8];
        let f = serial_fft2_transposed(&grid, 8, 8);
        assert!((f[0].re - 64.0).abs() < 1e-3);
        for v in &f[1..] {
            assert!(v.abs() < 1e-3);
        }
    }

    #[test]
    fn rel_error_zero_on_identity() {
        let grid = Slab::whole(4, 4).data;
        assert_eq!(rel_error(&grid, &grid), 0.0);
    }

    #[test]
    fn fft3_matches_oracle_non_pow2() {
        // Mixed-radix extents on every axis (6 = 2·3, 10 = 2·5).
        let grid = Grid3::new(6, 4, 10);
        let data = crate::dist_fft::grid3::whole_grid(grid);
        let fast = serial_fft3_transposed(&data, grid);
        let slow = oracle_fft3_transposed(&data, grid);
        let err = rel_error(&fast, &slow);
        assert!(err < 1e-4, "rel err {err}");
    }

    #[test]
    fn fft3_impulse_transforms_to_constant() {
        let grid = Grid3::new(4, 2, 8);
        let mut data = vec![Complex32::ZERO; grid.elems()];
        data[0] = Complex32::ONE;
        for v in serial_fft3_transposed(&data, grid) {
            assert!((v.re - 1.0).abs() < 1e-5 && v.im.abs() < 1e-5);
        }
    }

    #[test]
    fn fft3_dc_energy() {
        let grid = Grid3::new(4, 4, 4);
        let data = vec![Complex32::ONE; grid.elems()];
        let f = serial_fft3_transposed(&data, grid);
        assert!((f[0].re - 64.0).abs() < 1e-3);
        for v in &f[1..] {
            assert!(v.abs() < 1e-3);
        }
    }
}
