//! Serial reference for the distributed transforms.
//!
//! Computes the same transposed-layout FFT a distributed run produces,
//! entirely on one thread with the native kernel — 2-D: row FFTs →
//! transpose → row FFTs; 3-D: z FFTs → transpose → y FFTs → transpose →
//! x FFTs. Real-domain (r2c) runs have packed-half-spectrum references
//! ([`serial_rfft2_packed_transposed`], [`serial_rfft3_packed_transposed`])
//! plus an O(n²) real-input DFT oracle ([`oracle_rdft`]) and
//! Hermitian-symmetry checks. Used by tests and the CLI's `--verify`
//! flag.

use super::grid3::Grid3;
use super::transpose::{place_chunk_transposed, transpose};
use crate::fft::complex::Complex32;
use crate::fft::plan::{Direction, PlanCache};
use crate::fft::real::rfft_rows_packed;

/// Serial transposed-output 2-D FFT of a row-major `rows × cols` grid.
/// Output is `cols × rows` (frequency-domain, transposed layout).
pub fn serial_fft2_transposed(data: &[Complex32], rows: usize, cols: usize) -> Vec<Complex32> {
    assert_eq!(data.len(), rows * cols);
    let mut work = data.to_vec();

    // Step 1: FFT each row (length cols).
    let plan_c = PlanCache::global().plan(cols, Direction::Forward);
    plan_c.execute_rows(&mut work);

    // Step 2+3: full transpose (what the communication + chunk transposes
    // accomplish across localities).
    let mut t = transpose(&work, rows, cols);

    // Step 4: FFT each row of the transposed grid (length rows).
    let plan_r = PlanCache::global().plan(rows, Direction::Forward);
    plan_r.execute_rows(&mut t);
    t
}

/// Serial packed-transposed-output 2-D real FFT of a row-major
/// `rows × cols` real grid — the reference for real-domain distributed
/// runs. Stage 1 r2c-packs every row into `cols/2` bins, then the
/// pipeline is identical to [`serial_fft2_transposed`]'s tail: output
/// is `(cols/2) × rows` in the packed-transposed layout the distributed
/// result assembles into (row 0 carries the transformed
/// DC + i·Nyquist packed column; unpack with
/// [`unpack_packed2_transposed`] for true bins).
pub fn serial_rfft2_packed_transposed(data: &[f32], rows: usize, cols: usize) -> Vec<Complex32> {
    assert_eq!(data.len(), rows * cols);
    assert!(cols % 2 == 0, "real reference needs an even first-axis length");

    // Step 1: r2c each row into the packed half-spectrum.
    let work = rfft_rows_packed(data, cols);

    // Steps 2+3: transpose the rows × cols/2 spectral grid.
    let mut t = transpose(&work, rows, cols / 2);

    // Step 4: FFT each spectral column (length rows).
    PlanCache::global().plan(rows, Direction::Forward).execute_rows(&mut t);
    t
}

/// Unpack a packed-transposed 2-D real spectrum (`(cols/2) × rows`, the
/// layout [`serial_rfft2_packed_transposed`] and the real-domain
/// distributed runs produce) into the true `(cols/2 + 1) × rows`
/// Hermitian-unique half-spectrum: row 0 holds the transform of the
/// packed DC + i·Nyquist column, which splits by conjugate symmetry
/// into the true bin-0 and Nyquist rows.
pub fn unpack_packed2_transposed(
    packed: &[Complex32],
    rows: usize,
    cols: usize,
) -> Vec<Complex32> {
    let m = cols / 2;
    assert!(cols % 2 == 0 && m >= 1, "need an even first-axis length");
    assert_eq!(packed.len(), m * rows, "packed spectrum shape mismatch");
    let mut out = Vec::with_capacity((m + 1) * rows);
    // Row 0: Z[r] = A[r] + i·B[r] with A/B the transforms of the real
    // DC/Nyquist columns, both Hermitian — split them.
    for r in 0..rows {
        let z = packed[r];
        let zc = packed[(rows - r) % rows].conj();
        out.push((z + zc).scale(0.5));
    }
    out.extend_from_slice(&packed[rows..]);
    for r in 0..rows {
        let z = packed[r];
        let zc = packed[(rows - r) % rows].conj();
        out.push((z - zc).mul_neg_i().scale(0.5));
    }
    out
}

/// Max deviation from the Hermitian self-symmetry a real input's
/// half-spectrum must satisfy: in the unpacked `(cols/2 + 1) × rows`
/// transposed layout, the DC row (0) and the Nyquist row (`cols/2`)
/// each obey `F[c][r] = conj(F[c][(rows−r) % rows])`.
pub fn hermitian_symmetry_error(half: &[Complex32], rows: usize, cols: usize) -> f32 {
    let m = cols / 2;
    assert_eq!(half.len(), (m + 1) * rows, "unpacked half-spectrum shape mismatch");
    let mut worst = 0.0f32;
    for &row in &[0usize, m] {
        for r in 0..rows {
            let a = half[row * rows + r];
            let b = half[row * rows + (rows - r) % rows].conj();
            worst = worst.max((a.re - b.re).abs().max((a.im - b.im).abs()));
        }
    }
    worst
}

/// O(n²) real-input DFT oracle: the `n/2 + 1` Hermitian-unique bins of
/// one real row, f64 accumulation — ground truth for the r2c kernel and
/// the real-domain distributed tests, tiny sizes only.
pub fn oracle_rdft(x: &[f32]) -> Vec<Complex32> {
    let n = x.len();
    assert!(n >= 1, "oracle needs a non-empty signal");
    let mut out = Vec::with_capacity(n / 2 + 1);
    for k in 0..=n / 2 {
        let (mut re, mut im) = (0.0f64, 0.0f64);
        for (j, &v) in x.iter().enumerate() {
            let theta = -2.0 * std::f64::consts::PI * (k * j) as f64 / n as f64;
            re += v as f64 * theta.cos();
            im += v as f64 * theta.sin();
        }
        out.push(Complex32::new(re as f32, im as f32));
    }
    out
}

/// Serial transposed-output 3-D FFT of a row-major `[i0][i1][i2]` grid.
/// Output is `[i2][i1][i0]` (frequency-domain, transposed layout) — the
/// global shape of the pencil pipeline's distributed result.
pub fn serial_fft3_transposed(data: &[Complex32], grid: Grid3) -> Vec<Complex32> {
    assert_eq!(data.len(), grid.elems());
    let mut work = data.to_vec();

    // Phase 1: FFT every z-row (length n2).
    PlanCache::global().plan(grid.n2, Direction::Forward).execute_rows(&mut work);
    serial_fft3_tail(work, grid)
}

/// Phases 2–5 of [`serial_fft3_transposed`]: the pipeline downstream of
/// the z-transform, shared with the real-domain reference (whose phase 1
/// is an r2c pack instead).
fn serial_fft3_tail(work: Vec<Complex32>, grid: Grid3) -> Vec<Complex32> {
    let (n0, n1, n2) = (grid.n0, grid.n1, grid.n2);
    assert_eq!(work.len(), grid.elems());

    // Transpose 1: [i0·n1 + i1][i2] → [i2][i0][i1] (what the
    // row-communicator exchange accomplishes across localities).
    let mut t = transpose(&work, n0 * n1, n2);

    // Phase 3: FFT every y-row (length n1).
    PlanCache::global().plan(n1, Direction::Forward).execute_rows(&mut t);

    // Transpose 2: per-i2 slice, [i0][i1] → [i1][i0] (the
    // column-communicator exchange).
    let mut out = vec![Complex32::ZERO; n0 * n1 * n2];
    for z in 0..n2 {
        place_chunk_transposed(
            &t[z * n0 * n1..(z + 1) * n0 * n1],
            n0,
            n1,
            &mut out[z * n0 * n1..(z + 1) * n0 * n1],
            n0,
            0,
        );
    }

    // Phase 5: FFT every x-row (length n0).
    PlanCache::global().plan(n0, Direction::Forward).execute_rows(&mut out);
    out
}

/// Serial packed-transposed-output 3-D real FFT: phase 1 r2c-packs
/// every z-row of the real `[i0][i1][i2]` grid into `n2/2` bins, then
/// phases 2–5 run the complex pipeline on the halved grid. Output is
/// `[i2'][i1][i0]` with `i2' < n2/2` (packed z-plane 0 carries
/// DC + i·Nyquist) — the global shape of a real-domain pencil run.
pub fn serial_rfft3_packed_transposed(data: &[f32], grid: Grid3) -> Vec<Complex32> {
    assert_eq!(data.len(), grid.elems());
    assert!(grid.n2 % 2 == 0, "real 3-D reference needs an even z-extent");
    let work = rfft_rows_packed(data, grid.n2);
    serial_fft3_tail(work, Grid3::new(grid.n0, grid.n1, grid.n2 / 2))
}

/// Oracle-grade 2-D DFT in the transposed `cols × rows` layout of
/// [`serial_fft2_transposed`]: O(n²) DFTs per axis, f64 accumulation —
/// ground truth for tests, tiny sizes only. Real-domain tests feed it
/// the complexified real grid and compare the Hermitian-unique rows
/// `0..=cols/2` against the unpacked distributed output.
pub fn oracle_fft2_transposed(data: &[Complex32], rows: usize, cols: usize) -> Vec<Complex32> {
    use crate::fft::dft::dft;
    assert_eq!(data.len(), rows * cols);
    // Row DFTs.
    let mut work: Vec<Complex32> = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        work.extend(dft(&data[r * cols..(r + 1) * cols]));
    }
    // Transpose.
    let t = transpose(&work, rows, cols);
    // Row DFTs again.
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..cols {
        out.extend(dft(&t[r * rows..(r + 1) * rows]));
    }
    out
}

/// Oracle-grade 3-D DFT in the same transposed `[i2][i1][i0]` layout as
/// [`serial_fft3_transposed`]: O(n²) DFTs per axis, f64 accumulation —
/// ground truth for tests, tiny sizes only.
pub fn oracle_fft3_transposed(data: &[Complex32], grid: Grid3) -> Vec<Complex32> {
    use crate::fft::dft::dft;
    let (n0, n1, n2) = (grid.n0, grid.n1, grid.n2);
    assert_eq!(data.len(), grid.elems());
    let mut work: Vec<Complex32> = Vec::with_capacity(grid.elems());
    for r in 0..n0 * n1 {
        work.extend(dft(&data[r * n2..(r + 1) * n2]));
    }
    let t = transpose(&work, n0 * n1, n2); // [i2][i0][i1]
    let mut t2: Vec<Complex32> = Vec::with_capacity(grid.elems());
    for r in 0..n2 * n0 {
        t2.extend(dft(&t[r * n1..(r + 1) * n1]));
    }
    let mut swapped = vec![Complex32::ZERO; grid.elems()]; // [i2][i1][i0]
    for z in 0..n2 {
        place_chunk_transposed(
            &t2[z * n0 * n1..(z + 1) * n0 * n1],
            n0,
            n1,
            &mut swapped[z * n0 * n1..(z + 1) * n0 * n1],
            n0,
            0,
        );
    }
    let mut out: Vec<Complex32> = Vec::with_capacity(grid.elems());
    for r in 0..n2 * n1 {
        out.extend(dft(&swapped[r * n0..(r + 1) * n0]));
    }
    out
}

/// Max |Δ| between two complex buffers, as interleaved f32 distance.
pub fn max_error(a: &[Complex32], b: &[Complex32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x.re - y.re).abs().max((x.im - y.im).abs()))
        .fold(0.0, f32::max)
}

/// Relative L2 error between complex buffers.
pub fn rel_error(a: &[Complex32], b: &[Complex32]) -> f64 {
    let fa: Vec<f32> = a.iter().flat_map(|c| [c.re, c.im]).collect();
    let fb: Vec<f32> = b.iter().flat_map(|c| [c.re, c.im]).collect();
    crate::util::testkit::rel_l2_error(&fa, &fb)
}

/// Byte-level all-to-all oracle: `rows[src][dst]` is what `src` sends
/// to `dst`; the result's `[dst][src]` is what `dst` must hold — a
/// plain matrix transpose. Every simulated all-to-all
/// ([`crate::simnet::collective_sim`]) is checked bitwise against this,
/// whatever delays, reorders, or faults the adversary injected.
pub fn oracle_all_to_all(rows: &[Vec<Vec<u8>>]) -> Vec<Vec<Vec<u8>>> {
    let n = rows.len();
    (0..n).map(|dst| (0..n).map(|src| rows[src][dst].clone()).collect()).collect()
}

/// Byte-level scatter oracle: rank `r` ends up holding exactly the
/// root's `r`-th chunk (as a single-entry row, matching the simulated
/// report's shape).
pub fn oracle_scatter(root_row: &[Vec<u8>]) -> Vec<Vec<Vec<u8>>> {
    root_row.iter().map(|chunk| vec![chunk.clone()]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist_fft::partition::{RealSlab, Slab};

    #[test]
    fn matches_oracle() {
        let grid = Slab::whole(8, 16).data;
        let fast = serial_fft2_transposed(&grid, 8, 16);
        let slow = oracle_fft2_transposed(&grid, 8, 16);
        assert!(rel_error(&fast, &slow) < 1e-4, "rel err {}", rel_error(&fast, &slow));
    }

    #[test]
    fn matches_oracle_non_pow2() {
        // Mixed-radix rows and columns (12 = 4·3, 20 = 4·5).
        let grid = Slab::whole(12, 20).data;
        let fast = serial_fft2_transposed(&grid, 12, 20);
        let slow = oracle_fft2_transposed(&grid, 12, 20);
        assert!(rel_error(&fast, &slow) < 1e-4, "rel err {}", rel_error(&fast, &slow));
    }

    #[test]
    fn impulse_transforms_to_constant() {
        let mut grid = vec![Complex32::ZERO; 4 * 8];
        grid[0] = Complex32::ONE;
        let f = serial_fft2_transposed(&grid, 4, 8);
        for v in f {
            assert!((v.re - 1.0).abs() < 1e-5 && v.im.abs() < 1e-5);
        }
    }

    #[test]
    fn dc_energy() {
        let grid = vec![Complex32::ONE; 8 * 8];
        let f = serial_fft2_transposed(&grid, 8, 8);
        assert!((f[0].re - 64.0).abs() < 1e-3);
        for v in &f[1..] {
            assert!(v.abs() < 1e-3);
        }
    }

    #[test]
    fn rel_error_zero_on_identity() {
        let grid = Slab::whole(4, 4).data;
        assert_eq!(rel_error(&grid, &grid), 0.0);
    }

    #[test]
    fn fft3_matches_oracle_non_pow2() {
        // Mixed-radix extents on every axis (6 = 2·3, 10 = 2·5).
        let grid = Grid3::new(6, 4, 10);
        let data = crate::dist_fft::grid3::whole_grid(grid);
        let fast = serial_fft3_transposed(&data, grid);
        let slow = oracle_fft3_transposed(&data, grid);
        let err = rel_error(&fast, &slow);
        assert!(err < 1e-4, "rel err {err}");
    }

    #[test]
    fn fft3_impulse_transforms_to_constant() {
        let grid = Grid3::new(4, 2, 8);
        let mut data = vec![Complex32::ZERO; grid.elems()];
        data[0] = Complex32::ONE;
        for v in serial_fft3_transposed(&data, grid) {
            assert!((v.re - 1.0).abs() < 1e-5 && v.im.abs() < 1e-5);
        }
    }

    /// The packed real 2-D reference must agree with the complex oracle
    /// on the Hermitian-unique half after unpacking — the ground-truth
    /// anchor of every real-domain distributed test.
    #[test]
    fn rfft2_packed_reference_matches_complex_oracle() {
        for (rows, cols) in [(8usize, 16usize), (12, 20), (6, 6)] {
            let real = RealSlab::whole(rows, cols).data;
            let packed = serial_rfft2_packed_transposed(&real, rows, cols);
            assert_eq!(packed.len(), (cols / 2) * rows);
            let half = unpack_packed2_transposed(&packed, rows, cols);

            // Complexified oracle: full cols × rows transposed spectrum.
            let cx: Vec<Complex32> = real.iter().map(|&v| Complex32::new(v, 0.0)).collect();
            let full = oracle_fft2_transposed(&cx, rows, cols);
            let expect = &full[..(cols / 2 + 1) * rows];
            let err = rel_error(&half, expect);
            assert!(err < 1e-4, "{rows}×{cols}: rel err {err}");

            // A real input's spectrum is Hermitian — DC and Nyquist rows
            // are self-conjugate.
            let sym = hermitian_symmetry_error(&half, rows, cols);
            assert!(sym < 1e-3, "{rows}×{cols}: Hermitian deviation {sym}");
        }
    }

    #[test]
    fn oracle_rdft_matches_complex_dft() {
        use crate::fft::dft::dft;
        let x: Vec<f32> = (0..10).map(|i| (i as f32 * 0.7).sin()).collect();
        let cx: Vec<Complex32> = x.iter().map(|&v| Complex32::new(v, 0.0)).collect();
        let full = dft(&cx);
        let half = oracle_rdft(&x);
        assert_eq!(half.len(), 6);
        let err = rel_error(&half, &full[..6]);
        assert!(err < 1e-5, "rel err {err}");
    }

    #[test]
    fn rfft3_packed_reference_matches_complexified_serial() {
        // The packed 3-D real reference equals: complexify, run the full
        // complex pipeline on the halved-z grid after manually packing
        // phase 1 — i.e. the tail refactor is consistent with itself and
        // the unpacked z-plane values match the complex 3-D transform on
        // the Hermitian-unique half... pinned here at the z-plane level:
        // every *non-packed* z-plane (i2' ≥ 1) of the real run must
        // bitwise-match the complex transform's plane i2'.
        let grid = Grid3::new(4, 6, 8);
        let real = crate::dist_fft::grid3::whole_grid_real(grid);
        let packed = serial_rfft3_packed_transposed(&real, grid);
        assert_eq!(packed.len(), 4 * 6 * 4);

        let cx: Vec<Complex32> = real.iter().map(|&v| Complex32::new(v, 0.0)).collect();
        let full = oracle_fft3_transposed(&cx, grid); // [i2][i1][i0]
        let plane = grid.n0 * grid.n1;
        for z in 1..grid.n2 / 2 {
            let err = rel_error(
                &packed[z * plane..(z + 1) * plane],
                &full[z * plane..(z + 1) * plane],
            );
            assert!(err < 1e-4, "z-plane {z}: rel err {err}");
        }
        // Packed plane 0 = FFT2(DC plane) + i·FFT2(Nyquist plane).
        let m = grid.n2 / 2;
        for i in 0..plane {
            let expect = full[i] + full[m * plane + i].mul_i();
            let got = packed[i];
            assert!(
                (got.re - expect.re).abs() < 1e-2 && (got.im - expect.im).abs() < 1e-2,
                "packed plane elem {i}: {got:?} vs {expect:?}"
            );
        }
    }

    #[test]
    fn hermitian_error_detects_asymmetry() {
        let (rows, cols) = (4usize, 4usize);
        let mut half = vec![Complex32::ZERO; (cols / 2 + 1) * rows];
        assert_eq!(hermitian_symmetry_error(&half, rows, cols), 0.0);
        half[1] = Complex32::new(0.0, 1.0); // breaks conj(F[0][3]) = F[0][1]
        assert!(hermitian_symmetry_error(&half, rows, cols) >= 1.0);
    }

    #[test]
    fn fft3_dc_energy() {
        let grid = Grid3::new(4, 4, 4);
        let data = vec![Complex32::ONE; grid.elems()];
        let f = serial_fft3_transposed(&data, grid);
        assert!((f[0].re - 64.0).abs() < 1e-3);
        for v in &f[1..] {
            assert!(v.abs() < 1e-3);
        }
    }
}
