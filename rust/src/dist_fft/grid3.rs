//! 3-D grid geometry and pencil decomposition over a `Pr × Pc` process
//! grid.
//!
//! The global `n0 × n1 × n2` complex grid is distributed so that every
//! locality always owns *one full dimension* (its pencils) and a 2-D
//! block of the other two. Three pencil orientations appear during the
//! 3-D FFT, connected by two transpose rounds:
//!
//! ```text
//! stage Z   [i0-block(Pr)] [i1-block(Pc)] [i2 full]   z-pencils
//!    │  FFT(z), then row-communicator all-to-all (Pc ranks)
//! stage Y   [i0-block(Pr)] [i2-block(Pc)] [i1 full]   y-pencils
//!    │  FFT(y), then column-communicator all-to-all (Pr ranks)
//! stage X   [i2-block(Pc)] [i1-block(Pr)] [i0 full]   x-pencils
//!    └  FFT(x) → transposed distributed output
//! ```
//!
//! Each stage stores its pencil row-major with the full dimension
//! contiguous, so every FFT phase is a plain row batch. The transpose
//! rounds are expressed as wire-format extraction
//! ([`extract_t1_bytes`] / [`extract_t2_bytes`]) and **chunk-granular**
//! placement ([`place_t1_slice`] / [`place_t2_slice`]): a placement
//! window may start at any element offset, so arriving wire chunks of
//! the pipelined collectives are transpose-placed the moment they land,
//! exactly like the 2-D slab path.

use super::transpose::place_chunk_slice_transposed;
use crate::fft::complex::{as_byte_slice, Complex32};
use crate::util::rng::Pcg32;

/// Global 3-D grid extents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid3 {
    /// Extent of the slowest dimension (x).
    pub n0: usize,
    /// Extent of the middle dimension (y).
    pub n1: usize,
    /// Extent of the fastest dimension (z).
    pub n2: usize,
}

impl Grid3 {
    /// A grid with the given extents.
    pub fn new(n0: usize, n1: usize, n2: usize) -> Self {
        Self { n0, n1, n2 }
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.n0 * self.n1 * self.n2
    }
}

impl std::fmt::Display for Grid3 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.n0, self.n1, self.n2)
    }
}

/// Parse an `x`-separated list of exactly `n` positive extents
/// (`"12x8x24"`, `"2x2"`) — the shared grammar of the [`Grid3`] and
/// [`ProcGrid`] `FromStr` impls.
fn parse_dims(s: &str, n: usize) -> Result<Vec<usize>, String> {
    let parts: Vec<&str> = s.split(['x', 'X', '×']).collect();
    if parts.len() != n {
        return Err(format!("expected {n} x-separated extents, got {s:?}"));
    }
    parts
        .into_iter()
        .map(|p| {
            let v: usize = p.trim().parse().map_err(|e| format!("bad extent {p:?}: {e}"))?;
            if v == 0 {
                return Err(format!("zero extent in {s:?}"));
            }
            Ok(v)
        })
        .collect()
}

impl std::str::FromStr for Grid3 {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let d = parse_dims(s, 3)?;
        Ok(Self { n0: d[0], n1: d[1], n2: d[2] })
    }
}

/// 2-D process grid: `pr` rows × `pc` columns of localities. Locality
/// `rank` sits at row `rank / pc`, column `rank % pc`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProcGrid {
    /// Process-grid rows (the column-communicator size).
    pub pr: usize,
    /// Process-grid columns (the row-communicator size).
    pub pc: usize,
}

impl ProcGrid {
    /// A `pr × pc` process grid.
    pub fn new(pr: usize, pc: usize) -> Self {
        Self { pr, pc }
    }

    /// Total locality count.
    pub fn n(&self) -> usize {
        self.pr * self.pc
    }

    /// `(row, column)` coordinates of a locality rank.
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        (rank / self.pc, rank % self.pc)
    }

    /// Locality rank at `(row, column)`.
    pub fn rank_of(&self, row: usize, col: usize) -> usize {
        row * self.pc + col
    }
}

impl std::fmt::Display for ProcGrid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.pr, self.pc)
    }
}

impl std::str::FromStr for ProcGrid {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let d = parse_dims(s, 2)?;
        Ok(Self { pr: d[0], pc: d[1] })
    }
}

/// Per-locality pencil extents, derived from a grid + process grid.
/// Construction *errors* (instead of panicking) when any dimension does
/// not divide — the CLI and bench harness surface this to the user.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PencilDims {
    /// The global grid.
    pub grid: Grid3,
    /// The process grid.
    pub proc: ProcGrid,
    /// `n0 / pr` — x-block held in stages Z and Y.
    pub d0: usize,
    /// `n1 / pc` — y-block held in stage Z.
    pub d1c: usize,
    /// `n1 / pr` — y-block held in stage X.
    pub d1r: usize,
    /// `n2 / pc` — z-block held in stages Y and X.
    pub d2c: usize,
}

impl PencilDims {
    /// Validate the decomposition and derive the block extents.
    pub fn new(grid: Grid3, proc: ProcGrid) -> anyhow::Result<Self> {
        anyhow::ensure!(proc.pr >= 1 && proc.pc >= 1, "process grid must be non-empty");
        anyhow::ensure!(grid.elems() > 0, "grid must be non-empty");
        anyhow::ensure!(
            grid.n0 % proc.pr == 0,
            "n0 = {} not divisible by Pr = {} (x-block of the z/y pencils)",
            grid.n0,
            proc.pr
        );
        anyhow::ensure!(
            grid.n1 % proc.pc == 0,
            "n1 = {} not divisible by Pc = {} (y-block of the z pencils)",
            grid.n1,
            proc.pc
        );
        anyhow::ensure!(
            grid.n2 % proc.pc == 0,
            "n2 = {} not divisible by Pc = {} (z-block of the y/x pencils)",
            grid.n2,
            proc.pc
        );
        anyhow::ensure!(
            grid.n1 % proc.pr == 0,
            "n1 = {} not divisible by Pr = {} (y-block of the x pencils)",
            grid.n1,
            proc.pr
        );
        Ok(Self {
            grid,
            proc,
            d0: grid.n0 / proc.pr,
            d1c: grid.n1 / proc.pc,
            d1r: grid.n1 / proc.pr,
            d2c: grid.n2 / proc.pc,
        })
    }

    /// Elements per locality (identical in every stage).
    pub fn local_elems(&self) -> usize {
        self.grid.elems() / self.proc.n()
    }

    /// Elements of one round-1 transpose chunk (per row-comm peer).
    pub fn t1_chunk_elems(&self) -> usize {
        self.d0 * self.d1c * self.d2c
    }

    /// Elements of one round-2 transpose chunk (per column-comm peer).
    pub fn t2_chunk_elems(&self) -> usize {
        self.d0 * self.d2c * self.d1r
    }
}

/// Deterministic synthetic signal for the stage-Z pencil at process-grid
/// position `(row_idx, col_idx)`. One RNG stream per global `(i0, i1)`
/// z-row makes the data decomposition-independent: every `(Pr, Pc)`
/// shape — and the serial [`whole_grid`] — generates bit-identical
/// global data (verification depends on this).
pub fn synthetic_pencil(dims: &PencilDims, row_idx: usize, col_idx: usize) -> Vec<Complex32> {
    let (d0, d1c, n2) = (dims.d0, dims.d1c, dims.grid.n2);
    let n1 = dims.grid.n1;
    let mut out = Vec::with_capacity(d0 * d1c * n2);
    for s in 0..d0 {
        let i0 = row_idx * d0 + s;
        for r in 0..d1c {
            let i1 = col_idx * d1c + r;
            let mut rng = Pcg32::with_stream(0x3D11_F0F0, (i0 * n1 + i1) as u64 + 1);
            for _ in 0..n2 {
                out.push(Complex32::new(rng.next_signal(), rng.next_signal()));
            }
        }
    }
    out
}

/// The whole global grid, `[i0][i1][i2]` row-major (serial reference) —
/// bit-identical to the union of every rank's [`synthetic_pencil`].
pub fn whole_grid(grid: Grid3) -> Vec<Complex32> {
    let dims = PencilDims::new(grid, ProcGrid::new(1, 1)).expect("1×1 always divides");
    synthetic_pencil(&dims, 0, 0)
}

/// Deterministic synthetic *real* signal for the stage-Z pencil at
/// process-grid position `(row_idx, col_idx)` — the real-domain (r2c)
/// input of the pencil pipeline. Same decomposition-independence scheme
/// as [`synthetic_pencil`] (one RNG stream per global `(i0, i1)` z-row,
/// distinct stream constant), one sample per element. `dims` is the
/// *input-side* decomposition: its `grid.n2` is the real z-extent,
/// twice the spectral extent phase 1 packs it into.
pub fn synthetic_pencil_real(dims: &PencilDims, row_idx: usize, col_idx: usize) -> Vec<f32> {
    let (d0, d1c, n2) = (dims.d0, dims.d1c, dims.grid.n2);
    let n1 = dims.grid.n1;
    let mut out = Vec::with_capacity(d0 * d1c * n2);
    for s in 0..d0 {
        let i0 = row_idx * d0 + s;
        for r in 0..d1c {
            let i1 = col_idx * d1c + r;
            let mut rng = Pcg32::with_stream(0x3D11_F0F1, (i0 * n1 + i1) as u64 + 1);
            for _ in 0..n2 {
                out.push(rng.next_signal());
            }
        }
    }
    out
}

/// The whole real global grid, `[i0][i1][i2]` row-major — bit-identical
/// to the union of every rank's [`synthetic_pencil_real`].
pub fn whole_grid_real(grid: Grid3) -> Vec<f32> {
    let dims = PencilDims::new(grid, ProcGrid::new(1, 1)).expect("1×1 always divides");
    synthetic_pencil_real(&dims, 0, 0)
}

/// Round-1 wire buffer: the part of a stage-Z pencil
/// (`[d0][d1c][n2]`) destined for row-comm peer `dest` — its z-block
/// `[dest·d2c, (dest+1)·d2c)` of every z-row — serialized in
/// `(s, r, z)` order as wire-format bytes.
pub fn extract_t1_bytes(data: &[Complex32], dims: &PencilDims, dest: usize) -> Vec<u8> {
    let (d0, d1c, d2c, n2) = (dims.d0, dims.d1c, dims.d2c, dims.grid.n2);
    assert_eq!(data.len(), d0 * d1c * n2, "stage-Z pencil shape mismatch");
    assert!(dest < dims.proc.pc, "row-comm peer {dest} out of range");
    let mut out = Vec::with_capacity(d0 * d1c * d2c * std::mem::size_of::<Complex32>());
    for s in 0..d0 {
        for r in 0..d1c {
            let base = (s * d1c + r) * n2 + dest * d2c;
            out.extend_from_slice(as_byte_slice(&data[base..base + d2c]));
        }
    }
    out
}

/// [`extract_t1_bytes`] without the wire serialization: the same chunk,
/// same `(s, r, z)` order, as elements — the own-rank block never
/// touches the fabric, so it skips the byte round-trip.
pub fn extract_t1_elems(data: &[Complex32], dims: &PencilDims, dest: usize) -> Vec<Complex32> {
    let (d0, d1c, d2c, n2) = (dims.d0, dims.d1c, dims.d2c, dims.grid.n2);
    assert_eq!(data.len(), d0 * d1c * n2, "stage-Z pencil shape mismatch");
    assert!(dest < dims.proc.pc, "row-comm peer {dest} out of range");
    let mut out = Vec::with_capacity(d0 * d1c * d2c);
    for s in 0..d0 {
        for r in 0..d1c {
            let base = (s * d1c + r) * n2 + dest * d2c;
            out.extend_from_slice(&data[base..base + d2c]);
        }
    }
    out
}

/// Place a window of the round-1 chunk arriving from row-comm peer
/// `src` into a stage-Y pencil (`[d0][d2c][n1]`): chunk element
/// `(s, r, z)` (see [`extract_t1_bytes`]) lands at
/// `out[s][z][src·d1c + r]`. `elem_offset` is the window's position in
/// the chunk's element stream — any element-aligned wire-chunk cut
/// works, including mid-row.
pub fn place_t1_slice(
    elems: &[Complex32],
    elem_offset: usize,
    dims: &PencilDims,
    out: &mut [Complex32],
    src: usize,
) {
    let (d1c, d2c, n1) = (dims.d1c, dims.d2c, dims.grid.n1);
    assert!(
        elem_offset + elems.len() <= dims.t1_chunk_elems(),
        "window [{elem_offset}, +{}) exceeds round-1 chunk",
        elems.len()
    );
    assert_eq!(out.len(), dims.d0 * d2c * n1, "stage-Y pencil shape mismatch");
    assert!(src < dims.proc.pc, "row-comm peer {src} out of range");
    // Within one s-slab the chunk is a `d1c × d2c` matrix (rows r,
    // columns z) landing transposed at column offset `src·d1c` of the
    // slab's `d2c × n1` destination — exactly the cache-blocked
    // transpose primitive. Walk the window one s-slab at a time.
    let blk = d1c * d2c;
    let mut i = 0;
    while i < elems.len() {
        let e = elem_offset + i;
        let s = e / blk;
        let in_blk = e % blk;
        let take = (blk - in_blk).min(elems.len() - i);
        let base = s * d2c * n1;
        place_chunk_slice_transposed(
            &elems[i..i + take],
            in_blk,
            d1c,
            d2c,
            &mut out[base..base + d2c * n1],
            n1,
            src * d1c,
        );
        i += take;
    }
}

/// Round-2 wire buffer: the part of a stage-Y pencil
/// (`[d0][d2c][n1]`) destined for column-comm peer `dest` — its
/// y-block `[dest·d1r, (dest+1)·d1r)` of every y-row — serialized in
/// `(s, k, y)` order as wire-format bytes.
pub fn extract_t2_bytes(data: &[Complex32], dims: &PencilDims, dest: usize) -> Vec<u8> {
    let (d0, d1r, d2c, n1) = (dims.d0, dims.d1r, dims.d2c, dims.grid.n1);
    assert_eq!(data.len(), d0 * d2c * n1, "stage-Y pencil shape mismatch");
    assert!(dest < dims.proc.pr, "column-comm peer {dest} out of range");
    let mut out = Vec::with_capacity(d0 * d2c * d1r * std::mem::size_of::<Complex32>());
    for s in 0..d0 {
        for k in 0..d2c {
            let base = (s * d2c + k) * n1 + dest * d1r;
            out.extend_from_slice(as_byte_slice(&data[base..base + d1r]));
        }
    }
    out
}

/// [`extract_t2_bytes`] without the wire serialization — see
/// [`extract_t1_elems`].
pub fn extract_t2_elems(data: &[Complex32], dims: &PencilDims, dest: usize) -> Vec<Complex32> {
    let (d0, d1r, d2c, n1) = (dims.d0, dims.d1r, dims.d2c, dims.grid.n1);
    assert_eq!(data.len(), d0 * d2c * n1, "stage-Y pencil shape mismatch");
    assert!(dest < dims.proc.pr, "column-comm peer {dest} out of range");
    let mut out = Vec::with_capacity(d0 * d2c * d1r);
    for s in 0..d0 {
        for k in 0..d2c {
            let base = (s * d2c + k) * n1 + dest * d1r;
            out.extend_from_slice(&data[base..base + d1r]);
        }
    }
    out
}

/// Place a window of the round-2 chunk arriving from column-comm peer
/// `src` into a stage-X pencil (`[d2c][d1r][n0]`): chunk element
/// `(s, k, y)` (see [`extract_t2_bytes`]) lands at
/// `out[k][y][src·d0 + s]`.
pub fn place_t2_slice(
    elems: &[Complex32],
    elem_offset: usize,
    dims: &PencilDims,
    out: &mut [Complex32],
    src: usize,
) {
    let (d0, d1r, d2c, n0) = (dims.d0, dims.d1r, dims.d2c, dims.grid.n0);
    assert!(
        elem_offset + elems.len() <= dims.t2_chunk_elems(),
        "window [{elem_offset}, +{}) exceeds round-2 chunk",
        elems.len()
    );
    assert_eq!(out.len(), d2c * d1r * n0, "stage-X pencil shape mismatch");
    assert!(src < dims.proc.pr, "column-comm peer {src} out of range");
    // The whole chunk is a `d0 × (d2c·d1r)` matrix (rows s, columns
    // k·d1r + y) landing transposed at column offset `src·d0` of the
    // `(d2c·d1r) × n0` stage-X pencil — one call into the cache-blocked
    // transpose primitive.
    place_chunk_slice_transposed(elems, elem_offset, d0, d2c * d1r, out, n0, src * d0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::complex::from_le_bytes;

    fn dims(grid: Grid3, pr: usize, pc: usize) -> PencilDims {
        PencilDims::new(grid, ProcGrid::new(pr, pc)).unwrap()
    }

    #[test]
    fn parse_grid_and_proc() {
        assert_eq!("12x8x24".parse::<Grid3>().unwrap(), Grid3::new(12, 8, 24));
        assert_eq!("2x2".parse::<ProcGrid>().unwrap(), ProcGrid::new(2, 2));
        assert!("12x8".parse::<Grid3>().is_err());
        assert!("0x8x24".parse::<Grid3>().is_err());
        assert!("2x2x2".parse::<ProcGrid>().is_err());
        assert!("ax2".parse::<ProcGrid>().is_err());
    }

    #[test]
    fn proc_grid_coords_roundtrip() {
        let p = ProcGrid::new(3, 4);
        for rank in 0..p.n() {
            let (r, c) = p.coords(rank);
            assert!(r < 3 && c < 4);
            assert_eq!(p.rank_of(r, c), rank);
        }
    }

    #[test]
    fn non_divisible_dims_return_errors() {
        // n0 % pr
        let e = PencilDims::new(Grid3::new(10, 8, 24), ProcGrid::new(4, 1)).unwrap_err();
        assert!(e.to_string().contains("n0"), "{e}");
        // n1 % pc
        let e = PencilDims::new(Grid3::new(12, 9, 24), ProcGrid::new(1, 4)).unwrap_err();
        assert!(e.to_string().contains("n1"), "{e}");
        // n2 % pc
        let e = PencilDims::new(Grid3::new(12, 8, 25), ProcGrid::new(1, 4)).unwrap_err();
        assert!(e.to_string().contains("n2"), "{e}");
        // n1 % pr (the stage-X constraint)
        let e = PencilDims::new(Grid3::new(12, 9, 24), ProcGrid::new(3, 1)).unwrap_err();
        assert!(e.to_string().contains("Pr"), "{e}");
        // The acceptance shapes all divide.
        for (pr, pc) in [(1, 4), (2, 2), (4, 1)] {
            assert!(PencilDims::new(Grid3::new(12, 8, 24), ProcGrid::new(pr, pc)).is_ok());
        }
    }

    #[test]
    fn pencils_tile_the_grid_exactly() {
        // Property: the union of every rank's synthetic pencil covers the
        // whole grid exactly once, bit-identically to the serial grid.
        let grid = Grid3::new(12, 8, 6);
        let whole = whole_grid(grid);
        for (pr, pc) in [(1, 1), (1, 4), (2, 2), (4, 1), (2, 4)] {
            let d = dims(grid, pr, pc);
            let mut covered = vec![0usize; grid.elems()];
            for rank in 0..d.proc.n() {
                let (ri, ci) = d.proc.coords(rank);
                let pencil = synthetic_pencil(&d, ri, ci);
                assert_eq!(pencil.len(), d.local_elems());
                for s in 0..d.d0 {
                    let i0 = ri * d.d0 + s;
                    for r in 0..d.d1c {
                        let i1 = ci * d.d1c + r;
                        for z in 0..grid.n2 {
                            let g = (i0 * grid.n1 + i1) * grid.n2 + z;
                            covered[g] += 1;
                            assert_eq!(
                                pencil[(s * d.d1c + r) * grid.n2 + z],
                                whole[g],
                                "{pr}x{pc} rank {rank} ({s},{r},{z})"
                            );
                        }
                    }
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "{pr}x{pc}: not an exact tiling");
        }
    }

    #[test]
    fn real_pencils_tile_the_grid_exactly() {
        let grid = Grid3::new(4, 6, 8);
        let whole = whole_grid_real(grid);
        for (pr, pc) in [(1, 2), (2, 2), (2, 1)] {
            let d = dims(grid, pr, pc);
            for rank in 0..d.proc.n() {
                let (ri, ci) = d.proc.coords(rank);
                let pencil = synthetic_pencil_real(&d, ri, ci);
                assert_eq!(pencil.len(), d.local_elems());
                for s in 0..d.d0 {
                    let i0 = ri * d.d0 + s;
                    for r in 0..d.d1c {
                        let i1 = ci * d.d1c + r;
                        for z in 0..grid.n2 {
                            assert_eq!(
                                pencil[(s * d.d1c + r) * grid.n2 + z],
                                whole[(i0 * grid.n1 + i1) * grid.n2 + z],
                                "{pr}x{pc} rank {rank} ({s},{r},{z})"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Simulate one full transpose round serially: every rank extracts
    /// its chunks, every destination places them.
    fn simulate_t1(d: &PencilDims, pencils: &[Vec<Complex32>]) -> Vec<Vec<Complex32>> {
        let pc = d.proc.pc;
        let pr = d.proc.pr;
        let mut ybufs: Vec<Vec<Complex32>> =
            (0..pr * pc).map(|_| vec![Complex32::ZERO; d.d0 * d.d2c * d.grid.n1]).collect();
        for ri in 0..pr {
            for src in 0..pc {
                for dest in 0..pc {
                    let bytes = extract_t1_bytes(&pencils[d.proc.rank_of(ri, src)], d, dest);
                    let elems = from_le_bytes(&bytes);
                    place_t1_slice(&elems, 0, d, &mut ybufs[d.proc.rank_of(ri, dest)], src);
                }
            }
        }
        ybufs
    }

    #[test]
    fn round_trip_transpose_is_identity() {
        // z-pencils → y-pencils → back: the inverse of the round-1
        // transpose is the same transpose on the axis-swapped grid
        // (n1 ↔ n2), so one function pair exercises both directions.
        let grid = Grid3::new(4, 6, 10);
        for (pr, pc) in [(1, 2), (2, 1), (2, 2), (1, 1)] {
            let d = dims(grid, pr, pc);
            let pencils: Vec<Vec<Complex32>> = (0..d.proc.n())
                .map(|rank| {
                    let (ri, ci) = d.proc.coords(rank);
                    synthetic_pencil(&d, ri, ci)
                })
                .collect();
            let ybufs = simulate_t1(&d, &pencils);
            // Inverse: same exchange on the swapped grid (y-rows become
            // the "z" of the swapped view).
            let swapped = dims(Grid3::new(grid.n0, grid.n2, grid.n1), pr, pc);
            let back = simulate_t1(&swapped, &ybufs);
            assert_eq!(back, pencils, "{pr}x{pc}: round trip must be the identity");
        }
    }

    #[test]
    fn round1_places_full_y_rows() {
        // After round 1 every y-row of a stage-Y pencil holds the full
        // global i1 range for its (i0, i2): check values against the
        // whole grid.
        let grid = Grid3::new(2, 6, 4);
        let d = dims(grid, 1, 2);
        let whole = whole_grid(grid);
        let pencils: Vec<Vec<Complex32>> =
            (0..2).map(|c| synthetic_pencil(&d, 0, c)).collect();
        let ybufs = simulate_t1(&d, &pencils);
        for (rank, ybuf) in ybufs.iter().enumerate() {
            for s in 0..d.d0 {
                for z in 0..d.d2c {
                    let i2 = rank * d.d2c + z;
                    for i1 in 0..grid.n1 {
                        assert_eq!(
                            ybuf[(s * d.d2c + z) * grid.n1 + i1],
                            whole[(s * grid.n1 + i1) * grid.n2 + i2],
                            "rank {rank} s={s} z={z} i1={i1}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn chunk_granular_placement_matches_whole_chunk() {
        // Placing a round-1 chunk window by window at awkward cut points
        // must equal the one-shot placement; same for round 2.
        let grid = Grid3::new(4, 6, 10);
        let d = dims(grid, 2, 2);
        let pencil = synthetic_pencil(&d, 1, 0);
        let chunk = from_le_bytes(&extract_t1_bytes(&pencil, &d, 1));
        let mut whole = vec![Complex32::ZERO; d.d0 * d.d2c * grid.n1];
        place_t1_slice(&chunk, 0, &d, &mut whole, 0);
        for window in [1usize, 3, 7, 11, chunk.len()] {
            let mut piecewise = vec![Complex32::ZERO; d.d0 * d.d2c * grid.n1];
            let mut off = 0;
            while off < chunk.len() {
                let hi = (off + window).min(chunk.len());
                place_t1_slice(&chunk[off..hi], off, &d, &mut piecewise, 0);
                off = hi;
            }
            assert_eq!(piecewise, whole, "t1 window {window}");
        }

        // Round 2 on a synthetic stage-Y buffer.
        let ybuf: Vec<Complex32> = (0..d.d0 * d.d2c * grid.n1)
            .map(|i| Complex32::new(i as f32, -(i as f32)))
            .collect();
        let chunk2 = from_le_bytes(&extract_t2_bytes(&ybuf, &d, 0));
        let mut whole2 = vec![Complex32::ZERO; d.d2c * d.d1r * grid.n0];
        place_t2_slice(&chunk2, 0, &d, &mut whole2, 1);
        for window in [1usize, 5, 8] {
            let mut piecewise = vec![Complex32::ZERO; d.d2c * d.d1r * grid.n0];
            let mut off = 0;
            while off < chunk2.len() {
                let hi = (off + window).min(chunk2.len());
                place_t2_slice(&chunk2[off..hi], off, &d, &mut piecewise, 1);
                off = hi;
            }
            assert_eq!(piecewise, whole2, "t2 window {window}");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds round-1 chunk")]
    fn t1_window_overflow_detected() {
        let d = dims(Grid3::new(2, 2, 2), 1, 2);
        let mut out = vec![Complex32::ZERO; d.d0 * d.d2c * d.grid.n1];
        let too_many = vec![Complex32::ZERO; d.t1_chunk_elems() + 1];
        place_t1_slice(&too_many, 0, &d, &mut out, 0);
    }

    #[test]
    fn elems_extraction_matches_wire_bytes() {
        // The own-rank fast path (elements) and the wire path (bytes)
        // must produce the same chunk — the pencil pipeline's bitwise
        // guarantee leans on this.
        let grid = Grid3::new(4, 6, 10);
        let d = dims(grid, 2, 2);
        let pencil = synthetic_pencil(&d, 0, 1);
        for dest in 0..d.proc.pc {
            assert_eq!(
                extract_t1_elems(&pencil, &d, dest),
                from_le_bytes(&extract_t1_bytes(&pencil, &d, dest)),
                "t1 dest {dest}"
            );
        }
        let ybuf: Vec<Complex32> = (0..d.d0 * d.d2c * grid.n1)
            .map(|i| Complex32::new(i as f32, 0.5 - i as f32))
            .collect();
        for dest in 0..d.proc.pr {
            assert_eq!(
                extract_t2_elems(&ybuf, &d, dest),
                from_le_bytes(&extract_t2_bytes(&ybuf, &d, dest)),
                "t2 dest {dest}"
            );
        }
    }

    #[test]
    fn chunk_elem_counts() {
        let d = dims(Grid3::new(12, 8, 24), 2, 2);
        assert_eq!(d.local_elems(), 12 * 8 * 24 / 4);
        // Round 1 ships (1 - 1/Pc), round 2 (1 - 1/Pr) of the local data.
        assert_eq!(d.t1_chunk_elems() * d.proc.pc, d.local_elems());
        assert_eq!(d.t2_chunk_elems() * d.proc.pr, d.local_elems());
    }
}
