//! # hpx-fft — HPX communication benchmark reproduction
//!
//! Reproduction of *"A HPX Communication Benchmark: Distributed FFT using
//! Collectives"* (Strack & Pflüger, CS.DC 2025): an HPX-style
//! asynchronous-many-task substrate with three parcelports (TCP / MPI /
//! LCI), collective operations, a distributed 2-D FFT built on them, an
//! FFTW3-MPI+pthreads-style baseline, and a calibrated discrete-event
//! network simulator that regenerates the paper's figures at cluster
//! scale. The FFT compute hot path can also run through an AOT-compiled
//! JAX/Pallas artifact via PJRT (see `python/compile/` and
//! [`runtime`]).
//!
//! See `docs/ARCHITECTURE.md` for the paper-section → module map and
//! the data-flow trace of a scatter-variant timestep.

#![warn(missing_docs)]
// Every `unsafe` operation must sit in its own `unsafe { }` block with a
// `// SAFETY:` justification, even inside `unsafe fn` — the granularity
// the Miri job in `.github/workflows/analysis.yml` audits.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod baseline;
pub mod bench_harness;
pub mod cli;
pub mod collectives;
pub mod config;
pub mod metrics;
pub mod dist_fft;
pub mod fft;
pub mod hpx;
pub mod obs;
pub mod parcelport;
pub mod runtime;
pub mod simnet;
pub mod task;
pub mod util;

/// One-line import for the request-based API: transform building
/// ([`TransformRequest`](crate::dist_fft::TransformRequest) and its
/// knob types) plus the resident service
/// ([`FftService`](crate::runtime::FftService) and its job types).
///
/// ```
/// use hpx_fft::prelude::*;
///
/// let report = TransformRequest::grid(16, 16)
///     .localities(2)
///     .threads(1)
///     .build()
///     .unwrap()
///     .run()
///     .unwrap();
/// assert!(report.rel_error.unwrap() < 1e-4);
/// ```
pub mod prelude {
    pub use crate::collectives::{AllToAllAlgo, ChunkPolicy};
    pub use crate::config::TransformSpec;
    pub use crate::dist_fft::driver::{ComputeEngine, Domain, ExecutionMode, Variant};
    pub use crate::dist_fft::grid3::{Grid3, ProcGrid};
    pub use crate::dist_fft::request::{
        Transform, TransformReport, TransformRequest, TransformTimings,
    };
    pub use crate::hpx::runtime::Cluster;
    pub use crate::parcelport::{NetModel, PortKind, PortStatsSnapshot};
    pub use crate::runtime::{
        AdmissionError, FftService, JobError, JobHandle, JobOutput, JobState, ServiceConfig,
        TenantMetrics,
    };
}
