//! `cargo bench --bench hotpath` — micro-benchmarks of the L3 hot paths.
//!
//! The §Perf instrumentation: per-operation timings for the pieces the
//! end-to-end runtime is made of. Used to find and verify the
//! optimizations recorded in EXPERIMENTS.md §Perf.

use hpx_fft::bench_harness::runner::time_us;
use hpx_fft::dist_fft::transpose::place_chunk_transposed;
use hpx_fft::fft::complex::Complex32;
use hpx_fft::fft::plan::{Direction, Plan, PlanCache};
use hpx_fft::hpx::mailbox::Mailbox;
use hpx_fft::hpx::parcel::{actions, Parcel, Payload};
use hpx_fft::task::ThreadPool;
use hpx_fft::util::rng::Pcg32;
use std::sync::Arc;

fn bench(name: &str, iters: usize, mut f: impl FnMut()) {
    // Warmup.
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let total_us = time_us(|| {
        for _ in 0..iters {
            f();
        }
    });
    let per = total_us / iters as f64;
    let (val, unit) = if per < 1.0 { (per * 1e3, "ns") } else { (per, "µs") };
    println!("{name:<44} {val:>10.1} {unit}/op   ({iters} iters)");
}

fn signal(n: usize, seed: u64) -> Vec<Complex32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
}

fn main() {
    println!("== hotpath micro-benchmarks ==\n");

    // FFT kernel.
    for log2n in [10usize, 12, 14] {
        let n = 1 << log2n;
        let plan = Plan::new(n);
        let mut buf = signal(n, 1);
        let flops = plan.flops();
        let mut last_us = 0.0;
        bench(&format!("fft radix2 n=2^{log2n}"), 2000 >> (log2n - 10), || {
            last_us = time_us(|| plan.execute(&mut buf, Direction::Forward));
        });
        println!(
            "{:<44} {:>10.2} GFLOP/s",
            format!("  → throughput n=2^{log2n}"),
            flops / last_us / 1e3
        );
    }

    // Batched rows, serial vs parallel.
    {
        let n = 1024;
        let rows = 256;
        let plan = PlanCache::global().plan(n);
        let mut buf = signal(rows * n, 2);
        bench("fft_rows 256×1024 serial", 20, || {
            hpx_fft::fft::batch::fft_rows_parallel(&mut buf, n, &plan, Direction::Forward, 1);
        });
        bench("fft_rows 256×1024 4 threads", 20, || {
            hpx_fft::fft::batch::fft_rows_parallel(&mut buf, n, &plan, Direction::Forward, 4);
        });
    }

    // Chunk transpose (the scatter variant's overlapped work).
    {
        let (r, c) = (256, 256);
        let chunk = signal(r * c, 3);
        let mut slab = vec![Complex32::ZERO; r * c];
        bench("place_chunk_transposed 256×256", 200, || {
            place_chunk_transposed(&chunk, r, c, &mut slab, r, 0);
        });
    }

    // Payload semantics: the LCI-vs-MPI difference in one number.
    {
        let payload = Payload::new(vec![0u8; 1 << 20]);
        bench("payload shallow clone (LCI path) 1 MiB", 100_000, || {
            let _ = payload.clone();
        });
        bench("payload deep copy (MPI eager path) 1 MiB", 2000, || {
            let _ = payload.deep_copy();
        });
    }

    // Mailbox matched deliver/recv.
    {
        let mb = Mailbox::new();
        let mut tag = 0u64;
        bench("mailbox deliver+recv", 100_000, || {
            mb.deliver(Parcel::new(0, 0, actions::P2P, tag, Payload::empty()));
            let _ = mb.recv(0, actions::P2P, tag);
            tag += 1;
        });
    }

    // Task spawn overhead.
    {
        let pool = Arc::new(ThreadPool::new(4));
        bench("threadpool spawn+get", 20_000, || {
            pool.spawn(|| 1usize).get();
        });
    }

    println!("\nhotpath done");
}
