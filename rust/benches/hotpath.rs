//! `cargo bench --bench hotpath` — micro-benchmarks of the L3 hot paths.
//!
//! The §Perf instrumentation: per-operation timings for the pieces the
//! end-to-end runtime is made of. Used to find and verify the
//! optimizations recorded in EXPERIMENTS.md §Perf.
//!
//! Pass `-- --smoke` for the CI fast path: iteration counts and the
//! collective workload shrink by ~an order of magnitude, and the run
//! still writes `bench_out/hotpath.csv` so regressions stay visible as
//! per-PR artifacts.

use hpx_fft::bench_harness::runner::time_us;
use hpx_fft::collectives::{AllToAllAlgo, ChunkPolicy, Communicator};
use hpx_fft::dist_fft::transpose::{place_chunk_transposed, BLOCK};
use hpx_fft::fft::complex::Complex32;
use hpx_fft::fft::plan::{Direction, Plan, PlanCache};
use hpx_fft::fft::{radix2, simd, twiddle};
use hpx_fft::hpx::mailbox::Mailbox;
use hpx_fft::hpx::parcel::{actions, Parcel, Payload};
use hpx_fft::hpx::runtime::Cluster;
use hpx_fft::parcelport::{NetModel, PortKind};
use hpx_fft::task::ThreadPool;
use hpx_fft::util::rng::Pcg32;
use std::sync::Arc;

/// One CSV record: `(bench, us_per_op, gflops, gbytes_per_s)`; the two
/// roofline columns stay 0.0 when the bench has no natural flop/byte count.
type Row = (String, f64, f64, f64);

fn bench(rows: &mut Vec<Row>, name: &str, iters: usize, mut f: impl FnMut()) -> f64 {
    // Warmup.
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let total_us = time_us(|| {
        for _ in 0..iters {
            f();
        }
    });
    let per = total_us / iters as f64;
    let (val, unit) = if per < 1.0 { (per * 1e3, "ns") } else { (per, "µs") };
    println!("{name:<44} {val:>10.1} {unit}/op   ({iters} iters)");
    rows.push((name.to_string(), per, 0.0, 0.0));
    per
}

fn signal(n: usize, seed: u64) -> Vec<Complex32> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| Complex32::new(rng.next_signal(), rng.next_signal())).collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // Iteration divisor for the smoke path.
    let div = if smoke { 10 } else { 1 };
    let mut rows: Vec<Row> = Vec::new();
    println!("== hotpath micro-benchmarks{} ==\n", if smoke { " (smoke)" } else { "" });
    println!("simd tier: {} ({} lanes)\n", simd::tier().name(), simd::tier().lanes());

    // FFT kernel, power-of-two path (split-radix over SIMD butterflies).
    for log2n in [10usize, 12, 14] {
        let n = 1 << log2n;
        let plan = Plan::new(n, Direction::Forward);
        let mut buf = signal(n, 1);
        let flops = plan.flops();
        let mut last_us = 0.0;
        bench(
            &mut rows,
            &format!("fft plan(split-radix) n=2^{log2n}"),
            ((2000 >> (log2n - 10)) / div).max(1),
            || {
                last_us = time_us(|| plan.execute(&mut buf));
            },
        );
        rows.last_mut().unwrap().2 = flops / last_us / 1e3;
        println!(
            "{:<44} {:>10.2} GFLOP/s",
            format!("  → throughput n=2^{log2n}"),
            flops / last_us / 1e3
        );
    }

    // The dispatch comparison: the planned power-of-two path (split-radix
    // over SIMD butterflies) against the raw iterative radix-2 reference
    // kernel it replaced.
    {
        let n = 1usize << 12;
        let plan = Plan::new(n, Direction::Forward);
        let (tw, br) = (twiddle::forward_table(n), twiddle::bit_reverse_table(n));
        let iters = (2000 / div).max(1);
        let mut buf = signal(n, 21);
        let mut planned_us = 0.0;
        bench(&mut rows, "fft planned pow2 n=2^12", iters, || {
            planned_us = time_us(|| plan.execute(&mut buf));
        });
        let mut buf2 = signal(n, 21);
        let mut raw_us = 0.0;
        bench(&mut rows, "fft raw radix2 kernel n=2^12", iters, || {
            raw_us = time_us(|| radix2::fft_in_place(&mut buf2, &tw, &br));
        });
        println!(
            "{:<44} {:>9.2}×   (<1.0 expected: split-radix vs scalar radix-2)",
            "  → planned/raw ratio",
            planned_us / raw_us.max(1e-9)
        );
    }

    // Tentpole acceptance, compute half: lane-parallel vs scalar radix-2
    // combines at the sizes the criteria pin (n ∈ {1024, 4096}). One op =
    // one full combine stage: n/2 butterflies at 10 flops each.
    {
        for n in [1024usize, 4096] {
            let half = n / 2;
            let tw = twiddle::half_table(n, false);
            let flops = 10.0 * half as f64;
            let iters = (400_000 / (n / 1024) / div).max(1);
            let mut lo = signal(half, 31);
            let mut hi = signal(half, 32);
            let simd_us =
                bench(&mut rows, &format!("combine radix2 simd n={n}"), iters, || {
                    simd::butterfly_radix2(&mut lo, &mut hi, &tw);
                });
            rows.last_mut().unwrap().2 = flops / simd_us / 1e3;
            let mut lo = signal(half, 31);
            let mut hi = signal(half, 32);
            let scalar_us =
                bench(&mut rows, &format!("combine radix2 scalar n={n}"), iters, || {
                    simd::butterfly_radix2_scalar(&mut lo, &mut hi, &tw);
                });
            rows.last_mut().unwrap().2 = flops / scalar_us / 1e3;
            println!(
                "{:<44} {:>9.2}×   (tier: {})",
                format!("  → simd speedup n={n}"),
                scalar_us / simd_us.max(1e-9),
                simd::tier().name()
            );
            // CI smoke gate: with a vector tier active, the dispatched
            // combine must not lose to its scalar twin.
            if smoke && n == 4096 && simd::tier() != simd::SimdTier::Scalar {
                assert!(
                    simd_us <= scalar_us * 1.05,
                    "simd combine slower than scalar at n=4096: {simd_us:.3} vs {scalar_us:.3} µs"
                );
            }
        }
    }

    // Mixed-radix path: composite (4·2·5·5·5) and prime (Bluestein).
    for n in [1000usize, 1013] {
        let plan = Plan::new(n, Direction::Forward);
        let mut buf = signal(n, 22);
        let mut scratch = hpx_fft::fft::FftScratch::new();
        let flops = plan.flops();
        let mut last_us = 0.0;
        let label = if plan.uses_bluestein() {
            format!("fft bluestein n={n}")
        } else {
            format!("fft mixed-radix n={n} {:?}", plan.radices())
        };
        bench(&mut rows, &label, (1000 / div).max(1), || {
            last_us = time_us(|| plan.execute_with_scratch(&mut buf, &mut scratch));
        });
        rows.last_mut().unwrap().2 = flops / last_us / 1e3;
        println!(
            "{:<44} {:>10.2} GFLOP/s",
            format!("  → throughput n={n}"),
            flops / last_us / 1e3
        );
    }

    // Batched rows, serial vs pool-parallel; pow2 and mixed-radix.
    {
        let n = 1024;
        let rows_n = 256;
        let plan = PlanCache::global().plan(n, Direction::Forward);
        let mut buf = signal(rows_n * n, 2);
        bench(&mut rows, "fft_rows 256×1024 serial", (20 / div).max(1), || {
            hpx_fft::fft::batch::fft_rows_parallel(&mut buf, n, &plan, 1);
        });
        bench(&mut rows, "fft_rows 256×1024 pool×4", (20 / div).max(1), || {
            hpx_fft::fft::batch::fft_rows_parallel(&mut buf, n, &plan, 4);
        });

        let n = 1000; // non-power-of-two sweep point
        let plan = PlanCache::global().plan(n, Direction::Forward);
        let mut buf = signal(rows_n * n, 23);
        bench(&mut rows, "fft_rows 256×1000 serial", (20 / div).max(1), || {
            hpx_fft::fft::batch::fft_rows_parallel(&mut buf, n, &plan, 1);
        });
        bench(&mut rows, "fft_rows 256×1000 pool×4", (20 / div).max(1), || {
            hpx_fft::fft::batch::fft_rows_parallel(&mut buf, n, &plan, 4);
        });
    }

    // Tentpole acceptance, data-movement half: cache-blocked vs naive
    // chunk transpose into a preallocated slab. The roofline column is
    // bytes/s with every element read once and written once (r·c·8·2 B).
    {
        for (r, c) in [(256usize, 256usize), (1024, 1024)] {
            let chunk = signal(r * c, 3);
            let mut slab = vec![Complex32::ZERO; r * c];
            let bytes = (r * c * 8 * 2) as f64;
            let iters = (2000 / (r / 256) / (c / 256) / div).max(1);
            let tiled_us = bench(
                &mut rows,
                &format!("transpose tiled {r}x{c} (B={BLOCK})"),
                iters,
                || {
                    place_chunk_transposed(&chunk, r, c, &mut slab, r, 0);
                },
            );
            rows.last_mut().unwrap().3 = bytes / tiled_us / 1e3;
            let naive_us =
                bench(&mut rows, &format!("transpose naive {r}x{c}"), iters, || {
                    for rr in 0..r {
                        for cc in 0..c {
                            slab[cc * r + rr] = chunk[rr * c + cc];
                        }
                    }
                });
            rows.last_mut().unwrap().3 = bytes / naive_us / 1e3;
            println!(
                "{:<44} {:>9.2}×   ({:.2} vs {:.2} GB/s)",
                format!("  → tiled speedup {r}x{c}"),
                naive_us / tiled_us.max(1e-9),
                bytes / tiled_us / 1e3,
                bytes / naive_us / 1e3
            );
        }
    }

    // Payload semantics: the LCI-vs-MPI difference in one number.
    {
        let payload = Payload::new(vec![0u8; 1 << 20]);
        bench(&mut rows, "payload shallow clone (LCI path) 1 MiB", 100_000 / div, || {
            let _ = payload.clone();
        });
        bench(&mut rows, "payload slice (wire chunk) 1 MiB→64 KiB", 100_000 / div, || {
            let _ = payload.slice(512 * 1024, 64 * 1024);
        });
        bench(&mut rows, "payload deep copy (MPI eager path) 1 MiB", 2000 / div, || {
            let _ = payload.deep_copy();
        });
    }

    // Mailbox matched deliver/recv.
    {
        let mb = Mailbox::new();
        let mut tag = 0u64;
        bench(&mut rows, "mailbox deliver+recv", 100_000 / div, || {
            mb.deliver(Parcel::new(0, 0, actions::P2P, tag, Payload::empty()));
            let _ = mb.recv(0, actions::P2P, tag);
            tag += 1;
        });
    }

    // Task spawn overhead.
    {
        let pool = Arc::new(ThreadPool::new(4));
        bench(&mut rows, "threadpool spawn+get", 20_000 / div, || {
            pool.spawn(|| 1usize).get();
        });
    }

    // Observability overhead: with tracing disabled the span constructor
    // is one relaxed atomic load and must stay cheap enough to leave
    // compiled into every hot layer; the enabled cost (ring-buffer
    // record) is reported alongside for contrast.
    {
        let iters = 1_000_000 / div;
        let disabled_us = bench(&mut rows, "obs span disabled (gate check)", iters, || {
            let _g = hpx_fft::obs::span("bench", "gate", 0);
        });
        bench(&mut rows, "obs instant disabled (gate check)", iters, || {
            hpx_fft::obs::instant("bench", "gate", 0);
        });
        {
            let session = hpx_fft::obs::session();
            bench(&mut rows, "obs span enabled (ring record)", (200_000 / div).max(1), || {
                let _g = hpx_fft::obs::span("bench", "gate", 0);
            });
            drop(session.finish());
        }
        // CI smoke gate: the disabled-mode hot path must stay within a
        // few nanoseconds — tracing is compiled in everywhere, so any
        // regression here taxes every chunk send in the codebase.
        if smoke {
            assert!(
                disabled_us <= 0.025,
                "disabled tracing gate costs {:.2} ns/op (budget 25 ns)",
                disabled_us * 1e3
            );
        }
    }

    // Conformance-checker overhead: in a plain release build (this
    // bench) the checker is compiled out — `ACTIVE` is false and every
    // hook is an empty inline function — so "zero overhead when off" is
    // a measured, asserted property rather than a claim. With the
    // checker compiled in but disarmed, the gate is one relaxed load.
    {
        use hpx_fft::collectives::conformance;
        assert_eq!(
            conformance::ACTIVE,
            cfg!(any(debug_assertions, feature = "conformance")),
            "conformance checker must be compiled out exactly when ungated"
        );
        let disarmed_us =
            bench(&mut rows, "conformance hook disarmed (gate check)", 1_000_000 / div, || {
                conformance::probe();
            });
        if smoke {
            assert!(
                disarmed_us <= 0.025,
                "disarmed conformance gate costs {:.2} ns/op (budget 25 ns)",
                disarmed_us * 1e3
            );
        }
    }

    // The tentpole comparison: monolithic pairwise vs pipelined chunked
    // all-to-all (exchange + unpack into the destination buffer) on the
    // LCI fabric under the IB-HDR wire model — the ISSUE's N=8 / 4 MiB
    // acceptance scenario (shrunk in smoke mode).
    {
        let n = if smoke { 4 } else { 8 };
        let per_rank: usize = if smoke { 256 * 1024 } else { 4 << 20 };
        let policy = ChunkPolicy::new(if smoke { 64 * 1024 } else { 1 << 20 }, 4);
        let reps = if smoke { 3 } else { 5 };
        let cluster =
            Cluster::new(n, PortKind::Lci, Some(NetModel::infiniband_hdr())).expect("cluster");

        // Setup (communicator, send pool, buffers) happens before the
        // per-rank timer, so the µs/op numbers track the exchange+unpack
        // itself; the reported rep is the slowest rank of the best rep.
        let mut measure_best = |label: &str, chunked: bool| -> f64 {
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let times = cluster.run(|ctx| {
                    let comm = Communicator::from_ctx(ctx);
                    comm.set_chunk_policy(policy);
                    comm.warm_chunk_pool();
                    let chunks: Vec<Payload> =
                        (0..n).map(|_| Payload::new(vec![0u8; per_rank])).collect();
                    let mut dest = vec![0u8; n * per_rank];
                    let t0 = std::time::Instant::now();
                    if chunked {
                        comm.all_to_all_chunked_each(chunks, |src, off, p| {
                            dest[src * per_rank + off..src * per_rank + off + p.len()]
                                .copy_from_slice(p.as_bytes());
                        });
                    } else {
                        for (src, p) in comm
                            .all_to_all(chunks, AllToAllAlgo::Pairwise)
                            .into_iter()
                            .enumerate()
                        {
                            dest[src * per_rank..(src + 1) * per_rank]
                                .copy_from_slice(p.as_bytes());
                        }
                    }
                    std::hint::black_box(dest[0]);
                    t0.elapsed().as_secs_f64() * 1e6
                });
                best = best.min(times.into_iter().fold(0.0, f64::max));
            }
            println!("{label:<44} {best:>10.1} µs/op   ({reps} reps, best)");
            rows.push((label.to_string(), best, 0.0, 0.0));
            best
        };

        let mono = measure_best(&format!("a2a+unpack pairwise N={n} {per_rank}B"), false);
        let chunked = measure_best(
            &format!(
                "a2a+unpack pairwise-chunked N={n} {}x{}",
                policy.chunk_bytes, policy.inflight
            ),
            true,
        );
        println!(
            "{:<44} {:>9.2}×   ({per_rank} B/rank, netmodel on)",
            "  → chunked speedup over monolithic",
            mono / chunked
        );
        // (The speedup ratio is printed only — the CSV column is strictly
        // µs/op so regression tooling can diff it across runs.)
        let st = cluster.fabric().stats();
        println!(
            "{:<44} {:>10} B   (zero-copy pinned)",
            "  → LCI bytes copied during both runs",
            st.bytes_copied
        );
    }

    // The async acceptance comparison: blocking vs future-chained
    // scatter-variant distributed FFT on the NetModel-charged LCI port.
    // The wire model's time_scale is raised so modeled wire time is a
    // significant fraction of a step — the regime where overlap pays —
    // while the grid stays CI-sized.
    {
        use hpx_fft::dist_fft::driver::{
            self as fft_driver, ComputeEngine, DistFftConfig, Domain, ExecutionMode, Variant,
        };
        let n = 4;
        let grid = if smoke { 128usize } else { 256 };
        let net = NetModel { time_scale: 16.0, ..NetModel::infiniband_hdr() };
        let cluster = Cluster::new(n, PortKind::Lci, Some(net)).expect("cluster");
        let reps = if smoke { 2 } else { 4 };
        let base = DistFftConfig {
            rows: grid,
            cols: grid,
            localities: n,
            port: PortKind::Lci,
            variant: Variant::Scatter,
            algo: AllToAllAlgo::HpxRoot,
            chunk: ChunkPolicy::new(8 * 1024, 4),
            exec: ExecutionMode::Blocking,
            domain: Domain::Complex,
            threads_per_locality: 1,
            net: Some(net),
            engine: ComputeEngine::Native,
            verify: false,
        };
        // Kept on the deprecated entry point on purpose: this bench also
        // exercises the compatibility shim path.
        #[allow(deprecated)]
        let mut best_of = |label: &str, exec: ExecutionMode| -> (f64, f64) {
            let cfg = DistFftConfig { exec, ..base.clone() };
            let mut best_total = f64::INFINITY;
            let mut best_overlap = 0.0;
            for _ in 0..reps {
                let report = fft_driver::run_on(&cluster, &cfg).expect("dist fft");
                let t = report.critical_path.total_us;
                if t < best_total {
                    best_total = t;
                    best_overlap = report.critical_path.overlap_us;
                }
            }
            println!("{label:<44} {best_total:>10.1} µs/op   ({reps} reps, best)");
            rows.push((label.to_string(), best_total, 0.0, 0.0));
            (best_total, best_overlap)
        };
        let (blocking_us, _) = best_of(
            &format!("distfft scatter blocking {grid}x{grid} lci+net"),
            ExecutionMode::Blocking,
        );
        let (async_us, overlap_us) = best_of(
            &format!("distfft scatter async {grid}x{grid} lci+net"),
            ExecutionMode::Async,
        );
        println!(
            "{:<44} {:>9.2}×   ({overlap_us:.1} µs of wire time hidden)",
            "  → async speedup over blocking",
            blocking_us / async_us
        );
        rows.push(("distfft scatter async overlap_us".to_string(), overlap_us, 0.0, 0.0));
    }

    // 2-D-vs-3-D transpose volume (same total elements) on the
    // NetModel-charged LCI port: the 2-D slab pipeline moves (1 − 1/N)
    // of each locality's data in its single transpose; the 3-D pencil
    // pipeline moves (1 − 1/Pc) + (1 − 1/Pr) across its two
    // sub-communicator rounds — more volume, but in smaller,
    // group-scoped messages. Per-round bytes and wall µs side by side.
    {
        use hpx_fft::dist_fft::driver::{
            self as fft_driver, ComputeEngine, DistFftConfig, Domain, ExecutionMode, Variant,
        };
        use hpx_fft::dist_fft::grid3::{Grid3, ProcGrid};
        use hpx_fft::dist_fft::pencil::{self, Pencil3Config};

        let n = 4usize;
        let (pr, pc) = (2usize, 2usize);
        let net = NetModel { time_scale: 16.0, ..NetModel::infiniband_hdr() };
        // Same total elements: 256² = 64·32·32 (smoke: 128² = 32·32·16).
        let (rows2d, cols2d) = if smoke { (128usize, 128usize) } else { (256, 256) };
        let grid3 =
            if smoke { Grid3::new(32, 32, 16) } else { Grid3::new(64, 32, 32) };
        assert_eq!(rows2d * cols2d, grid3.elems(), "equal-volume comparison");
        let reps = if smoke { 2 } else { 4 };
        const ELEM: usize = 8;
        let local_bytes = rows2d * cols2d / n * ELEM;

        // 2-D slab: one transpose of (1 − 1/N) of the local slab.
        let cluster2d = Cluster::new(n, PortKind::Lci, Some(net)).expect("cluster");
        let cfg2d = DistFftConfig {
            rows: rows2d,
            cols: cols2d,
            localities: n,
            port: PortKind::Lci,
            variant: Variant::Scatter,
            algo: AllToAllAlgo::HpxRoot,
            chunk: ChunkPolicy::new(8 * 1024, 4),
            exec: ExecutionMode::Blocking,
            domain: Domain::Complex,
            threads_per_locality: 1,
            net: Some(net),
            engine: ComputeEngine::Native,
            verify: false,
        };
        let mut best2d = f64::INFINITY;
        #[allow(deprecated)]
        for _ in 0..reps {
            let report = fft_driver::run_on(&cluster2d, &cfg2d).expect("2d fft");
            best2d = best2d.min(report.critical_path.comm_us);
        }
        let bytes2d = local_bytes * (n - 1) / n;
        println!(
            "{:<44} {best2d:>10.1} µs/op   ({bytes2d} B/locality, 1 round)",
            format!("transpose 2d slab {rows2d}x{cols2d} N={n}")
        );
        rows.push((format!("transpose 2d slab {rows2d}x{cols2d}"), best2d, 0.0, 0.0));

        // 3-D pencil: two sub-communicator rounds.
        let cluster3d = Cluster::new(n, PortKind::Lci, Some(net)).expect("cluster");
        let cfg3d = Pencil3Config {
            grid: grid3,
            proc: ProcGrid::new(pr, pc),
            port: PortKind::Lci,
            chunk: ChunkPolicy::new(8 * 1024, 4),
            exec: ExecutionMode::Blocking,
            domain: Domain::Complex,
            threads_per_locality: 1,
            net: Some(net),
            engine: ComputeEngine::Native,
            verify: false,
        };
        let (mut best_t1, mut best_t2, mut best_sum) = (0.0, 0.0, f64::INFINITY);
        #[allow(deprecated)]
        for _ in 0..reps {
            let report = pencil::run_on(&cluster3d, &cfg3d).expect("3d fft");
            let cp = report.critical_path;
            if cp.t1_comm_us + cp.t2_comm_us < best_sum {
                best_sum = cp.t1_comm_us + cp.t2_comm_us;
                best_t1 = cp.t1_comm_us;
                best_t2 = cp.t2_comm_us;
            }
        }
        let bytes_t1 = local_bytes * (pc - 1) / pc;
        let bytes_t2 = local_bytes * (pr - 1) / pr;
        println!(
            "{:<44} {best_t1:>10.1} µs/op   ({bytes_t1} B/locality, row comm)",
            format!("transpose 3d pencil {grid3} t1 {pr}x{pc}")
        );
        println!(
            "{:<44} {best_t2:>10.1} µs/op   ({bytes_t2} B/locality, col comm)",
            format!("transpose 3d pencil {grid3} t2 {pr}x{pc}")
        );
        println!(
            "{:<44} {:>9.2}×   (3d moves {} B vs 2d {} B per locality)",
            "  → 3d/2d transpose wall-time ratio",
            best_sum / best2d.max(1e-9),
            bytes_t1 + bytes_t2,
            bytes2d
        );
        rows.push((format!("transpose 3d pencil t1 {pr}x{pc}"), best_t1, 0.0, 0.0));
        rows.push((format!("transpose 3d pencil t2 {pr}x{pc}"), best_t2, 0.0, 0.0));
    }

    // CSV artifact for the CI bench-smoke job. The two roofline columns
    // are 0 where the bench has no natural flop or byte count.
    let out_dir = "bench_out";
    let csv_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(name, us, gflops, gbytes)| {
            vec![name.clone(), us.to_string(), gflops.to_string(), gbytes.to_string()]
        })
        .collect();
    hpx_fft::metrics::csv::write_csv(
        format!("{out_dir}/hotpath.csv"),
        &["bench", "us_per_op", "gflops", "gbytes_per_s"],
        &csv_rows,
    )
    .expect("write hotpath.csv");
    println!("\nCSV written to {out_dir}/hotpath.csv");
    println!("hotpath done");
}
