//! `cargo bench --bench fig4_all_to_all` — paper Fig. 4.
//!
//! Strong scaling of the distributed FFT with the HPX *all-to-all*
//! collective (root-funneled), per parcelport, vs the FFTW3-like
//! baseline: live hybrid at laptop scale + simnet at the paper's
//! 2^14×2^14 on 1–16 nodes. Honours `HPXFFT_BENCH_QUICK=1`.

use hpx_fft::bench_harness::fig45::{self, System};
use hpx_fft::config::BenchConfig;
use hpx_fft::dist_fft::driver::Variant;
use hpx_fft::parcelport::PortKind;

fn main() {
    let quick = std::env::var("HPXFFT_BENCH_QUICK").is_ok();
    let config = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    println!("== bench fig4_all_to_all ==\n");
    let points = fig45::run(&config, Variant::AllToAll).expect("fig4 sweep");
    print!("{}", fig45::report(&points, Variant::AllToAll, &config, &config.out_dir).expect("report"));

    // Paper-shape check: LCI fastest HPX port at 16 nodes (sim).
    let sim = |sys| {
        points
            .iter()
            .filter(|p| p.system == sys)
            .map(|p| (p.nodes, p.sim_us))
            .max_by_key(|(n, _)| *n)
            .map(|(_, t)| t)
            .unwrap_or(f64::NAN)
    };
    let lci = sim(System::Hpx(PortKind::Lci));
    let mpi = sim(System::Hpx(PortKind::Mpi));
    println!(
        "\nshape {}: LCI ({lci:.0} µs) vs MPI ({mpi:.0} µs) at max nodes",
        if lci <= mpi { "OK" } else { "WARN" }
    );
}
