//! `cargo bench --bench fig3_chunk` — paper Fig. 3.
//!
//! Chunk-size scaling of the scatter collective on two localities, all
//! three parcelports, live hybrid + analytic model. Paper methodology:
//! mean over reps with 95% CI. Honours `HPXFFT_BENCH_QUICK=1`.

use hpx_fft::bench_harness::fig3;
use hpx_fft::config::BenchConfig;

fn main() {
    let quick = std::env::var("HPXFFT_BENCH_QUICK").is_ok();
    let mut config = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    config.out_dir = "bench_out".into();
    println!("== bench fig3_chunk: {} reps/point ==\n", config.reps);
    let points = fig3::run(&config).expect("fig3 sweep");
    print!("{}", fig3::report(&points, &config.out_dir).expect("report"));

    // Paper-shape assertions (soft: warn, don't crash the bench) — on
    // the monolithic scatter, the paper's configuration.
    let mean = |port, bytes| {
        points
            .iter()
            .find(|p| {
                p.port == port
                    && p.bytes == bytes
                    && p.algo == hpx_fft::collectives::ScatterAlgo::Linear
            })
            .map(|p| p.live.mean())
            .unwrap_or(f64::NAN)
    };
    use hpx_fft::parcelport::PortKind::*;
    let small = *config.chunk_sizes.first().unwrap();
    for (a, b, what) in [
        (Lci, Mpi, "LCI < MPI at small chunks"),
        (Mpi, Tcp, "MPI < TCP at small chunks"),
    ] {
        if mean(a, small) >= mean(b, small) {
            println!("WARN: expected {what}: {} vs {}", mean(a, small), mean(b, small));
        } else {
            println!("shape OK: {what}");
        }
    }
}
