//! `cargo bench --bench fig5_scatter` — paper Fig. 5.
//!
//! Strong scaling with the *N-scatter* variant (transpose overlapped
//! with communication) — the paper's proposed improvement — per
//! parcelport, vs the FFTW3-like baseline. The headline claim lives
//! here: HPX+LCI beats FFTW3 MPI+X. Honours `HPXFFT_BENCH_QUICK=1`.

use hpx_fft::bench_harness::fig45::{self, System};
use hpx_fft::config::BenchConfig;
use hpx_fft::dist_fft::driver::Variant;
use hpx_fft::parcelport::PortKind;

fn main() {
    let quick = std::env::var("HPXFFT_BENCH_QUICK").is_ok();
    let config = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    println!("== bench fig5_scatter ==\n");
    let points = fig45::run(&config, Variant::Scatter).expect("fig5 sweep");
    print!("{}", fig45::report(&points, Variant::Scatter, &config, &config.out_dir).expect("report"));

    let at_max = |sys| {
        points
            .iter()
            .filter(|p| p.system == sys)
            .map(|p| (p.nodes, p.sim_us))
            .max_by_key(|(n, _)| *n)
            .map(|(_, t)| t)
            .unwrap_or(f64::NAN)
    };
    let lci = at_max(System::Hpx(PortKind::Lci));
    let fftw = at_max(System::Fftw3);
    println!(
        "\nheadline shape {}: hpx-lci {lci:.0} µs vs fftw3 {fftw:.0} µs (speedup {:.2}×)",
        if lci < fftw { "OK" } else { "WARN" },
        fftw / lci
    );
}
