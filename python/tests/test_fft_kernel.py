"""Pallas FFT kernel vs the jnp.fft oracle — the core L1 correctness
signal. Hypothesis sweeps shapes; fixed cases pin the analytic
properties (impulse, tone, linearity, Parseval)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import fft_kernel, ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rand_planes(rng, batch, length):
    return (
        jnp.asarray(rng.standard_normal((batch, length)), dtype=jnp.float32),
        jnp.asarray(rng.standard_normal((batch, length)), dtype=jnp.float32),
    )


def assert_matches_ref(x_re, x_im, atol=2e-3, rtol=2e-3):
    got_re, got_im = fft_kernel.fft_rows(x_re, x_im)
    want_re, want_im = ref.fft_rows_ref(x_re, x_im)
    scale = float(jnp.max(jnp.abs(want_re)) + jnp.max(jnp.abs(want_im)) + 1.0)
    np.testing.assert_allclose(got_re, want_re, atol=atol * scale, rtol=rtol)
    np.testing.assert_allclose(got_im, want_im, atol=atol * scale, rtol=rtol)


@hypothesis.given(
    log_batch=st.integers(min_value=0, max_value=5),
    log_len=st.integers(min_value=1, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_ref_shape_sweep(log_batch, log_len, seed):
    rng = np.random.default_rng(seed)
    x_re, x_im = rand_planes(rng, 1 << log_batch, 1 << log_len)
    assert_matches_ref(x_re, x_im)


def test_impulse_gives_constant():
    x_re = jnp.zeros((2, 64), dtype=jnp.float32).at[:, 0].set(1.0)
    x_im = jnp.zeros((2, 64), dtype=jnp.float32)
    out_re, out_im = fft_kernel.fft_rows(x_re, x_im)
    np.testing.assert_allclose(out_re, np.ones((2, 64)), atol=1e-4)
    np.testing.assert_allclose(out_im, np.zeros((2, 64)), atol=1e-4)


def test_single_tone_lands_in_bin():
    n, bin_ = 128, 5
    t = np.arange(n)
    x_re = jnp.asarray(np.cos(2 * np.pi * bin_ * t / n)[None, :], dtype=jnp.float32)
    x_im = jnp.asarray(np.sin(2 * np.pi * bin_ * t / n)[None, :], dtype=jnp.float32)
    out_re, out_im = fft_kernel.fft_rows(x_re, x_im)
    assert abs(float(out_re[0, bin_]) - n) < 1e-2
    mask = np.ones(n, bool)
    mask[bin_] = False
    assert float(np.max(np.abs(np.asarray(out_re)[0, mask]))) < 1e-2
    assert float(np.max(np.abs(out_im))) < 1e-2


@hypothesis.given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_linearity(seed):
    rng = np.random.default_rng(seed)
    a_re, a_im = rand_planes(rng, 4, 256)
    b_re, b_im = rand_planes(rng, 4, 256)
    alpha = float(rng.standard_normal())
    s_re, s_im = fft_kernel.fft_rows(alpha * a_re + b_re, alpha * a_im + b_im)
    fa_re, fa_im = fft_kernel.fft_rows(a_re, a_im)
    fb_re, fb_im = fft_kernel.fft_rows(b_re, b_im)
    np.testing.assert_allclose(s_re, alpha * fa_re + fb_re, atol=1e-2, rtol=1e-3)
    np.testing.assert_allclose(s_im, alpha * fa_im + fb_im, atol=1e-2, rtol=1e-3)


def test_parseval():
    rng = np.random.default_rng(7)
    x_re, x_im = rand_planes(rng, 8, 512)
    out_re, out_im = fft_kernel.fft_rows(x_re, x_im)
    e_time = float(jnp.sum(x_re**2 + x_im**2))
    e_freq = float(jnp.sum(out_re**2 + out_im**2)) / 512
    assert abs(e_time - e_freq) < 1e-3 * e_time


@pytest.mark.parametrize("block_rows", [1, 2, 8, 32])
def test_block_rows_equivalence(block_rows):
    """Tiling must not change results: every block size agrees."""
    rng = np.random.default_rng(11)
    x_re, x_im = rand_planes(rng, 32, 128)
    base_re, base_im = fft_kernel.fft_rows(x_re, x_im, block_rows=32)
    got_re, got_im = fft_kernel.fft_rows(x_re, x_im, block_rows=block_rows)
    np.testing.assert_allclose(got_re, base_re, atol=1e-4)
    np.testing.assert_allclose(got_im, base_im, atol=1e-4)


def test_split_factors_balanced():
    assert fft_kernel.split_factors(1024) == (32, 32)
    assert fft_kernel.split_factors(2048) == (32, 64)
    assert fft_kernel.split_factors(2) == (1, 2)
    with pytest.raises(ValueError):
        fft_kernel.split_factors(24)


def test_dft_constants_unit_modulus():
    d1r, d1i, d2r, d2i, twr, twi = fft_kernel.dft_constants(256)
    np.testing.assert_allclose(np.asarray(d1r) ** 2 + np.asarray(d1i) ** 2,
                               np.ones_like(d1r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(twr) ** 2 + np.asarray(twi) ** 2,
                               np.ones_like(twr), atol=1e-5)


def test_vmem_budget_respected():
    """default_block_rows must keep the estimated footprint under 8 MiB
    for every realistic shape."""
    for batch, length in [(64, 256), (256, 256), (1024, 4096), (64, 16384)]:
        br = fft_kernel.default_block_rows(batch, length)
        assert batch % br == 0
        assert fft_kernel.vmem_bytes(br, length) <= 8 * 2**20 or br == 1


def test_bad_shapes_rejected():
    x = jnp.zeros((3, 64), dtype=jnp.float32)  # batch 3 not divisible by 2
    with pytest.raises(ValueError):
        fft_kernel.fft_rows(x, x, block_rows=2)
    y = jnp.zeros((2, 64), dtype=jnp.float32)
    with pytest.raises(ValueError):
        fft_kernel.fft_rows(y, jnp.zeros((2, 32), dtype=jnp.float32))
