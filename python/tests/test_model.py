"""L2 model-level checks: the full four-step pipeline vs jnp.fft.fft2,
plus shape/lowering sanity for the AOT entry points."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=10, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rand_grid(seed, rows, cols):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.standard_normal((rows, cols)), dtype=jnp.float32),
        jnp.asarray(rng.standard_normal((rows, cols)), dtype=jnp.float32),
    )


@hypothesis.given(
    log_r=st.integers(min_value=1, max_value=7),
    log_c=st.integers(min_value=1, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fft2_matches_ref(log_r, log_c, seed):
    rows, cols = 1 << log_r, 1 << log_c
    x_re, x_im = rand_grid(seed, rows, cols)
    got_re, got_im = model.fft2_transposed_model(x_re, x_im)
    want_re, want_im = ref.fft2_transposed_ref(x_re, x_im)
    scale = float(jnp.max(jnp.abs(want_re)) + jnp.max(jnp.abs(want_im)) + 1.0)
    np.testing.assert_allclose(got_re, want_re, atol=2e-3 * scale, rtol=2e-3)
    np.testing.assert_allclose(got_im, want_im, atol=2e-3 * scale, rtol=2e-3)
    assert got_re.shape == (cols, rows)  # transposed layout


def test_fft_rows_model_shape():
    x_re, x_im = rand_grid(0, 8, 64)
    out_re, out_im = model.fft_rows_model(x_re, x_im)
    assert out_re.shape == (8, 64) and out_im.shape == (8, 64)


def test_lowering_produces_hlo_text():
    text = aot.lower_fft_rows(4, 32)
    assert "HloModule" in text
    # interpret=True must have decayed the pallas call into plain HLO —
    # no Mosaic custom-calls allowed in a CPU-loadable artifact.
    assert "mosaic" not in text.lower()


def test_fft2_lowering_produces_hlo_text():
    text = aot.lower_fft2(16, 32)
    assert "HloModule" in text
    assert "mosaic" not in text.lower()


def test_lowered_rows_executes_same_as_eager():
    spec = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    compiled = jax.jit(model.fft_rows_model).lower(spec, spec).compile()
    x_re, x_im = rand_grid(1, 4, 64)
    got_re, got_im = compiled(x_re, x_im)
    want_re, want_im = model.fft_rows_model(x_re, x_im)
    np.testing.assert_allclose(got_re, want_re, atol=1e-5)
    np.testing.assert_allclose(got_im, want_im, atol=1e-5)


def test_parse_shapes():
    assert aot.parse_shapes("64x256, 8X8") == [(64, 256), (8, 8)]
    assert aot.parse_shapes("") == []


def test_flops_positive_and_scales():
    f1 = model.flops_fft_rows(64, 256)
    f2 = model.flops_fft_rows(128, 256)
    assert f2 == 2 * f1 > 0
