"""Tiled Pallas transpose vs jnp — shape sweep + tiling equivalence."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import transpose_kernel

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("ci")


@hypothesis.given(
    log_r=st.integers(min_value=0, max_value=9),
    log_c=st.integers(min_value=0, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_jnp_transpose(log_r, log_c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1 << log_r, 1 << log_c)),
                    dtype=jnp.float32)
    got = transpose_kernel.transpose(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x).T)


@pytest.mark.parametrize("tile", [(1, 1), (2, 4), (8, 8), (64, 32)])
def test_tiling_equivalence(tile):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((64, 128)), dtype=jnp.float32)
    got = transpose_kernel.transpose(x, *tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x).T)


def test_involution():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((32, 256)), dtype=jnp.float32)
    back = transpose_kernel.transpose(transpose_kernel.transpose(x))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_complex_planes():
    rng = np.random.default_rng(6)
    re = jnp.asarray(rng.standard_normal((16, 64)), dtype=jnp.float32)
    im = jnp.asarray(rng.standard_normal((16, 64)), dtype=jnp.float32)
    t_re, t_im = transpose_kernel.transpose_complex(re, im)
    np.testing.assert_array_equal(np.asarray(t_re), np.asarray(re).T)
    np.testing.assert_array_equal(np.asarray(t_im), np.asarray(im).T)


def test_bad_tile_rejected():
    x = jnp.zeros((10, 10), dtype=jnp.float32)
    with pytest.raises(ValueError):
        transpose_kernel.transpose(x, 3, 5)


def test_default_tile_divides():
    for rows, cols in [(64, 256), (1, 1), (512, 128), (2, 1024)]:
        tr, tc = transpose_kernel.default_tile(rows, cols)
        assert rows % tr == 0 and cols % tc == 0
        assert tr <= 256 and tc <= 256
