"""L2: the per-locality compute graph, calling the L1 kernels.

Two jittable entry points are AOT-lowered per shape by `aot.py`:

- ``fft_rows_model`` — step 1 / step 4 of the distributed algorithm:
  forward-FFT every row of the locality's (batch, L) slab. This is the
  function the Rust coordinator executes through PJRT on its request
  path.
- ``fft2_transposed_model`` — the whole four-step pipeline for a single
  locality (row FFTs → tiled Pallas transpose → row FFTs), used by the
  `pjrt_fft` example and as the L2-level integration check.

Both consume/produce separate re/im f32 planes (the PJRT ABI — the Rust
side views its `Complex32` AoS buffers as planes at the boundary).
"""

import jax.numpy as jnp

from .kernels import fft_kernel, transpose_kernel

__all__ = ["fft_rows_model", "fft2_transposed_model"]


def fft_rows_model(x_re, x_im):
    """Row-wise forward FFT. Returns a (re, im) tuple."""
    out_re, out_im = fft_kernel.fft_rows(x_re, x_im)
    return out_re, out_im


def fft2_transposed_model(x_re, x_im):
    """Transposed-layout 2-D FFT of one (rows, cols) grid.

    Mirrors the distributed four-step structure exactly: the transpose in
    the middle is what the communication step + chunk placements perform
    across localities.
    """
    # Step 1: row FFTs (length cols).
    a_re, a_im = fft_kernel.fft_rows(x_re, x_im)
    # Steps 2+3: transpose (Pallas tiled kernel).
    t_re, t_im = transpose_kernel.transpose_complex(a_re, a_im)
    # Step 4: row FFTs of the transposed grid (length rows).
    out_re, out_im = fft_kernel.fft_rows(t_re, t_im)
    return out_re, out_im


def flops_fft_rows(batch: int, length: int) -> float:
    """Four-step FLOP count: 4 real matmuls per stage + twiddle.

    2 stages × 4 matmuls × 2·B·L·L_i ops + 6·B·L twiddle flops — the
    number used for the MXU-utilization estimate in DESIGN.md §Perf.
    """
    l1, l2 = fft_kernel.split_factors(length)
    stage1 = 4 * 2 * batch * l2 * l1 * l1
    stage2 = 4 * 2 * batch * l1 * l2 * l2
    twiddle = 6 * batch * length
    return float(stage1 + stage2 + twiddle)
