"""AOT: lower the L2 model to HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is one compiled-shape entry point:

    artifacts/fft_rows_b{B}_l{L}.hlo.txt     — fft_rows_model on (B, L)
    artifacts/fft2_t_r{R}_c{C}.hlo.txt       — fft2_transposed_model on (R, C)

plus ``artifacts/manifest.txt`` (one line per artifact:
``kind batch len file``) which the Rust artifact registry parses. Python
runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.

Usage:
    python -m compile.aot --out-dir ../artifacts \
        [--rows-shapes 64x256,256x64] [--fft2-shapes 256x256]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default shape set: covers the quickstart / example configs
# (grid 256×256 on 1/2/4 localities) at build time. Benchmarks that need
# other shapes list them via --rows-shapes.
DEFAULT_ROWS_SHAPES = [(64, 256), (128, 256), (256, 256), (64, 512)]
DEFAULT_FFT2_SHAPES = [(256, 256)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True; the Rust
    side unwraps with to_tuple2)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fft_rows(batch: int, length: int) -> str:
    spec = jax.ShapeDtypeStruct((batch, length), jnp.float32)
    return to_hlo_text(jax.jit(model.fft_rows_model).lower(spec, spec))


def lower_fft2(rows: int, cols: int) -> str:
    spec = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    return to_hlo_text(jax.jit(model.fft2_transposed_model).lower(spec, spec))


def parse_shapes(text: str):
    out = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        a, b = part.lower().split("x")
        out.append((int(a), int(b)))
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--rows-shapes", default=None,
                    help="comma-separated BxL list for fft_rows artifacts")
    ap.add_argument("--fft2-shapes", default=None,
                    help="comma-separated RxC list for fft2 artifacts")
    args = ap.parse_args()

    rows_shapes = (parse_shapes(args.rows_shapes)
                   if args.rows_shapes else DEFAULT_ROWS_SHAPES)
    fft2_shapes = (parse_shapes(args.fft2_shapes)
                   if args.fft2_shapes else DEFAULT_FFT2_SHAPES)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []

    for batch, length in rows_shapes:
        name = f"fft_rows_b{batch}_l{length}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_fft_rows(batch, length)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(("fft_rows", batch, length, name))
        print(f"wrote {path} ({len(text)} chars)")

    for rows, cols in fft2_shapes:
        name = f"fft2_t_r{rows}_c{cols}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_fft2(rows, cols)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(("fft2_t", rows, cols, name))
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest_path, "w") as f:
        f.write("# kind batch len file — parsed by rust/src/runtime/artifact.rs\n")
        for kind, a, b, name in manifest:
            f.write(f"{kind} {a} {b} {name}\n")
    print(f"wrote {manifest_path} ({len(manifest)} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
