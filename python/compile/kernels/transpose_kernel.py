"""L1 Pallas kernel: tiled 2-D transpose.

Step 2+3 of the four-step distributed FFT is, per locality, a transpose.
On GPU one would stage tiles through shared memory to coalesce both the
read and the write side; the TPU formulation expresses the same idea with
``BlockSpec``: the grid walks (i, j) output tiles, the input index map
fetches the mirrored (j, i) tile into VMEM, and the kernel body is a plain
in-register transpose. The HBM↔VMEM tile schedule *is* the optimization —
there is no shared-memory choreography to port (DESIGN.md
§Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["transpose", "default_tile"]


def _transpose_tile_kernel(x_ref, out_ref):
    out_ref[...] = x_ref[...].T


def default_tile(rows: int, cols: int, max_tile: int = 256) -> tuple[int, int]:
    """Largest power-of-two tile dividing both dimensions (≤ max_tile).

    256×256 f32 = 256 KiB per tile side — two tiles double-buffered still
    clear VMEM comfortably.
    """
    def biggest(n):
        t = 1
        while t * 2 <= min(n, max_tile) and n % (t * 2) == 0:
            t *= 2
        return t
    return biggest(rows), biggest(cols)


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_c"))
def transpose(x, tile_r: int | None = None, tile_c: int | None = None):
    """Transpose a (rows, cols) f32 array via a tiled Pallas kernel."""
    rows, cols = x.shape
    if tile_r is None or tile_c is None:
        tile_r, tile_c = default_tile(rows, cols)
    if rows % tile_r or cols % tile_c:
        raise ValueError(f"tiles ({tile_r},{tile_c}) must divide shape {x.shape}")

    grid = (cols // tile_c, rows // tile_r)  # output tile coordinates
    return pl.pallas_call(
        _transpose_tile_kernel,
        grid=grid,
        # Output tile (i, j) covers out[i*tc:(i+1)*tc, j*tr:(j+1)*tr];
        # it needs input tile (j, i).
        in_specs=[pl.BlockSpec((tile_r, tile_c), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((tile_c, tile_r), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((cols, rows), x.dtype),
        interpret=True,
    )(x)


def transpose_complex(x_re, x_im, tile_r: int | None = None,
                      tile_c: int | None = None):
    """Transpose re/im planes together."""
    return (
        transpose(x_re, tile_r, tile_c),
        transpose(x_im, tile_r, tile_c),
    )
