"""L1 Pallas kernel: batched 1-D FFT via the four-step (DFT-matmul)
factorization.

The paper's compute hot-spot is FFTW's scalar butterfly kernel on EPYC
CPUs. Mechanically porting butterflies to TPU would waste the MXU, so the
kernel re-expresses the transform the way the systolic array wants it
(DESIGN.md §Hardware-Adaptation): a length-`L = L1·L2` FFT becomes two
small dense matmuls plus a pointwise twiddle:

    X[j1, j2] = x[j1·L2 + j2]                      (reshape)
    A[k1, j2] = Σ_{j1} W_{L1}^{j1·k1} · X[j1, j2]   (D1 @ X   — matmul)
    B[k1, j2] = A[k1, j2] · W_L^{k1·j2}             (twiddle  — pointwise)
    C[k1, k2] = Σ_{j2} B[k1, j2] · W_{L2}^{j2·k2}   (B @ D2   — matmul)
    x̂[k1 + L1·k2] = C[k1, k2]                       (transpose read-out)

Complex arithmetic is carried as separate re/im f32 planes (4 real
matmuls per DFT stage — bf16/f32 MXU-native). The DFT matrices and the
twiddle grid are precomputed on the host in f64 and passed as operands,
so the kernel body is transcendental-free.

The batch of rows is tiled by ``block_rows`` through ``BlockSpec`` so one
grid step holds a (block_rows, L) slab plus the constant matrices in
VMEM; `vmem_bytes` estimates the footprint for the §Perf analysis.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated on the interpret path and TPU
performance is estimated structurally (DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "dft_constants",
    "fft_rows",
    "split_factors",
    "vmem_bytes",
]


def split_factors(length: int) -> tuple[int, int]:
    """Balanced L = L1 * L2 factorization (both powers of two)."""
    if length & (length - 1) or length < 1:
        raise ValueError(f"length must be a power of two, got {length}")
    log2 = length.bit_length() - 1
    l1 = 1 << (log2 // 2)
    return l1, length // l1


def dft_constants(length: int):
    """DFT/twiddle constants for a length-`length` transform, computed
    with jnp ops so they are *part of the traced graph* (XLA constant-
    folds them at compile time) rather than closed-over host arrays —
    closed-over constants get hoisted into extra entry parameters by jax,
    which would break the 2-argument PJRT ABI the Rust runtime relies on.

    Angles are modulo-reduced before the division (`(j·k) mod n / n`), so
    every angle is an exact small integer ratio and f32 trig stays
    accurate at any transform length.

    Returns (d1_re, d1_im, d2_re, d2_im, tw_re, tw_im):
    D1[k, j] = W_{L1}^{jk}, D2[j, k] = W_{L2}^{jk} (symmetric),
    TW[k1, j2] = W_L^{k1 j2}; all with W_n = exp(-2πi/n).
    """
    l1, l2 = split_factors(length)

    def dft_matrix(n):
        j = jnp.arange(n, dtype=jnp.int32)
        m = (j[:, None] * j[None, :]) % n
        ang = (-2.0 * np.pi / n) * m.astype(jnp.float32)
        return jnp.cos(ang), jnp.sin(ang)

    d1r, d1i = dft_matrix(l1)
    d2r, d2i = dft_matrix(l2)
    k1 = jnp.arange(l1, dtype=jnp.int32)
    j2 = jnp.arange(l2, dtype=jnp.int32)
    m = (k1[:, None] * j2[None, :]) % length
    ang = (-2.0 * np.pi / length) * m.astype(jnp.float32)
    return d1r, d1i, d2r, d2i, jnp.cos(ang), jnp.sin(ang)


def _fft_block_kernel(l1, l2, xr_ref, xi_ref, d1r_ref, d1i_ref, d2r_ref,
                      d2i_ref, twr_ref, twi_ref, outr_ref, outi_ref):
    """One grid step: four-step FFT of a (block_rows, L) slab in VMEM."""
    block_rows = xr_ref.shape[0]
    xr = xr_ref[...].reshape(block_rows, l1, l2)
    xi = xi_ref[...].reshape(block_rows, l1, l2)
    d1r, d1i = d1r_ref[...], d1i_ref[...]
    d2r, d2i = d2r_ref[...], d2i_ref[...]
    twr, twi = twr_ref[...], twi_ref[...]

    # Stage 1: A = D1 @ X along the L1 axis (batched over rows).
    # einsum('kj,bjl->bkl') lowers to dot_general — MXU-shaped.
    mm1 = lambda m, x: jnp.einsum("kj,bjl->bkl", m, x,
                                  preferred_element_type=jnp.float32)
    ar = mm1(d1r, xr) - mm1(d1i, xi)
    ai = mm1(d1r, xi) + mm1(d1i, xr)

    # Stage 2: pointwise twiddle (broadcast over the batch axis).
    br = ar * twr - ai * twi
    bi = ar * twi + ai * twr

    # Stage 3: C = B @ D2 along the L2 axis.
    mm2 = lambda x, m: jnp.einsum("bkj,jl->bkl", x, m,
                                  preferred_element_type=jnp.float32)
    cr = mm2(br, d2r) - mm2(bi, d2i)
    ci = mm2(br, d2i) + mm2(bi, d2r)

    # Stage 4: transposed read-out — x̂[k1 + L1*k2] = C[k1, k2].
    outr_ref[...] = cr.transpose(0, 2, 1).reshape(block_rows, l1 * l2)
    outi_ref[...] = ci.transpose(0, 2, 1).reshape(block_rows, l1 * l2)


def fft_rows(x_re, x_im, *, block_rows: int | None = None):
    """Forward-FFT every row of (batch, L) re/im planes.

    Unnormalized, matching ``jnp.fft.fft`` / FFTW conventions. `L` and the
    batch must be powers of two (the batch so `block_rows` tiles evenly).
    """
    batch, length = x_re.shape
    if x_im.shape != x_re.shape:
        raise ValueError(f"re/im shape mismatch: {x_re.shape} vs {x_im.shape}")
    l1, l2 = split_factors(length)
    if block_rows is None:
        block_rows = default_block_rows(batch, length)
    if batch % block_rows:
        raise ValueError(f"batch {batch} not divisible by block_rows {block_rows}")
    d1r, d1i, d2r, d2i, twr, twi = dft_constants(length)

    grid = (batch // block_rows,)
    row_block = pl.BlockSpec((block_rows, length), lambda i: (i, 0))
    # Constants are replicated to every grid step (index_map → block 0).
    const = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))

    kernel = functools.partial(_fft_block_kernel, l1, l2)
    out_shape = [
        jax.ShapeDtypeStruct((batch, length), jnp.float32),
        jax.ShapeDtypeStruct((batch, length), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            row_block, row_block,
            const((l1, l1)), const((l1, l1)),
            const((l2, l2)), const((l2, l2)),
            const((l1, l2)), const((l1, l2)),
        ],
        out_specs=[row_block, row_block],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x_re, x_im, d1r, d1i, d2r, d2i, twr, twi)


def default_block_rows(batch: int, length: int,
                       vmem_budget: int = 8 * 2**20) -> int:
    """Largest power-of-two row block whose VMEM footprint fits the budget
    (default 8 MiB — half of a TPU core's ~16 MiB VMEM, leaving room for
    double-buffering)."""
    block = 1
    while (
        block * 2 <= batch
        and batch % (block * 2) == 0
        and vmem_bytes(block * 2, length) <= vmem_budget
    ):
        block *= 2
    return block


def vmem_bytes(block_rows: int, length: int) -> int:
    """Estimated VMEM working set of one grid step, bytes.

    in + out slabs (2 × 2 planes), the intermediate (2 planes, counted
    once — stages reuse), and the constant matrices.
    """
    l1, l2 = split_factors(length)
    slab = block_rows * length * 4
    consts = (2 * l1 * l1 + 2 * l2 * l2 + 2 * l1 * l2) * 4
    return 6 * slab + consts
