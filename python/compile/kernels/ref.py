"""Pure-jnp correctness oracles for the Pallas kernels.

The contract across the whole stack: unnormalized forward transform,
`jnp.fft` conventions — the same contract the Rust native kernel and the
distributed driver implement. Every kernel result is pinned against these
references by `python/tests/`.
"""

import jax.numpy as jnp

__all__ = ["fft_rows_ref", "fft2_transposed_ref", "transpose_ref"]


def fft_rows_ref(x_re, x_im):
    """Row-wise forward FFT of re/im planes via jnp.fft."""
    z = jnp.fft.fft(x_re.astype(jnp.complex64) + 1j * x_im.astype(jnp.complex64), axis=-1)
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


def fft2_transposed_ref(x_re, x_im):
    """Transposed-layout 2-D FFT: fft2 then transpose (the distributed
    driver's output convention, FFTW_MPI_TRANSPOSED_OUT)."""
    z = jnp.fft.fft2(x_re.astype(jnp.complex64) + 1j * x_im.astype(jnp.complex64))
    zt = z.T
    return jnp.real(zt).astype(jnp.float32), jnp.imag(zt).astype(jnp.float32)


def transpose_ref(x):
    """Plain transpose."""
    return x.T
